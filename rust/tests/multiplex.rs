//! Multiplexed-coordinator invariants:
//!
//! * **Bit-exact equivalence** — the multiplexed `MmServer` at depth 1
//!   (and depth 4) reproduces the sequential `Master`'s outputs
//!   bit-for-bit on the same seeded job stream, for every built-in
//!   `TaskSet`, with fault injection on. This relies on (a) faults
//!   being sampled at admission in submission order, (b) the canonical
//!   `SpanDecoder::solve`, and (c) `collect_all` pinning the decode set
//!   to the injected faults rather than thread timing.
//! * **Backpressure** — `submit` reports queue-full exactly at
//!   `queue_cap` outstanding jobs.

use std::time::Duration;

use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::coordinator::master::{Master, MasterConfig};
use ft_strassen::coordinator::server::{MmServer, ServerConfig};
use ft_strassen::coordinator::task::DispatchPlan;
use ft_strassen::coordinator::tier::{TenantSpec, TierConfig};
use ft_strassen::coordinator::worker::{Backend, FaultPlan};
use ft_strassen::linalg::matrix::Matrix;
use ft_strassen::sim::rng::Rng;

const JOBS: usize = 6;
const N: usize = 16;

fn fault_cfg(seed: u64) -> MasterConfig {
    MasterConfig {
        deadline: Duration::from_secs(30),
        fault: FaultPlan {
            p_fail: 0.15,
            p_straggle: 0.1,
            delay: Duration::from_millis(5),
        },
        seed,
        fallback_local: true,
        // Deterministic decode set: wait for every live reply.
        collect_all: true,
    }
}

fn job_stream(seed: u64) -> Vec<(Matrix, Matrix)> {
    let mut rng = Rng::seeded(seed);
    (0..JOBS)
        .map(|_| (Matrix::random(N, N, &mut rng), Matrix::random(N, N, &mut rng)))
        .collect()
}

/// The reference: one-job-at-a-time sequential master.
fn sequential_outputs(set: &TaskSet, seed: u64) -> Vec<Matrix> {
    let mut m = Master::new(set.clone(), Backend::Native, fault_cfg(seed));
    let out = job_stream(seed)
        .iter()
        .map(|(a, b)| m.multiply(a, b).unwrap().0)
        .collect();
    m.shutdown();
    out
}

/// The same stream through the multiplexed server at a given depth.
fn multiplexed_outputs(set: &TaskSet, seed: u64, depth: usize) -> Vec<Matrix> {
    let mut s = MmServer::new(
        set.clone(),
        Backend::Native,
        ServerConfig {
            master: fault_cfg(seed),
            queue_cap: 64,
            inflight_depth: depth,
        },
    );
    for (a, b) in job_stream(seed) {
        s.submit(a, b).unwrap();
    }
    let mut done = s.drain(usize::MAX).unwrap();
    assert_eq!(done.len(), JOBS);
    // Depth > 1 completes out of order; job ids are assigned in
    // submission order.
    done.sort_by_key(|c| c.id);
    let out = done.into_iter().map(|c| c.c).collect();
    s.shutdown();
    out
}

fn assert_bit_identical(set: &TaskSet, want: &[Matrix], got: &[Matrix], what: &str) {
    assert_eq!(want.len(), got.len());
    for (i, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(
            w.as_slice(),
            g.as_slice(),
            "{}: job {} diverged from sequential master ({what})",
            set.name,
            i + 1
        );
    }
}

#[test]
fn depth1_is_bit_identical_to_sequential_master_all_schemes() {
    for set in TaskSet::fig2_schemes() {
        let want = sequential_outputs(&set, 42);
        let got = multiplexed_outputs(&set, 42, 1);
        assert_bit_identical(&set, &want, &got, "depth 1");
    }
}

#[test]
fn depth4_is_bit_identical_to_sequential_master_all_schemes() {
    // Multiplexing must not change results: faults are sampled at
    // admission in submission order, so depth only affects overlap.
    for set in TaskSet::fig2_schemes() {
        let want = sequential_outputs(&set, 7);
        let got = multiplexed_outputs(&set, 7, 4);
        assert_bit_identical(&set, &want, &got, "depth 4");
    }
}

#[test]
fn tiered_serving_keeps_collect_all_depth_invariance() {
    // Regression for the facade drift satellite: the full serving tier
    // (tenant fair queuing + batching + encoded-operand cache) must not
    // change any job's bits vs the sequential master — faults are
    // (seed, job, item)-pure, job ids are assigned at submission, and
    // `collect_all` pins the decode set to the injected faults, so DRR
    // admission order, batch coalescing and cache reuse are all
    // bit-invisible.
    let set = TaskSet::strassen_winograd(2);
    let want = sequential_outputs(&set, 42);
    let mut s = MmServer::with_tier_config(
        DispatchPlan::flat(set.clone()),
        Backend::Native,
        TierConfig {
            master: fault_cfg(42),
            depth: 4,
            queue_cap: 64,
            tenants: vec![TenantSpec::new("heavy", 3, 8), TenantSpec::new("light", 1, 8)],
            batch_window: 3,
            cache_cap: 8,
        },
        None,
    );
    for (i, (a, b)) in job_stream(42).into_iter().enumerate() {
        let tenant = if i % 2 == 0 { "heavy" } else { "light" };
        s.submit_as(tenant, a, b).unwrap();
    }
    let mut done = s.drain(usize::MAX).unwrap();
    assert_eq!(done.len(), JOBS);
    done.sort_by_key(|c| c.id);
    let got: Vec<Matrix> = done.into_iter().map(|c| c.c).collect();
    assert_bit_identical(&set, &want, &got, "tenants+batch+cache depth 4");
    s.shutdown();
}

#[test]
fn outputs_match_dense_ground_truth_modulo_rounding() {
    // Sanity alongside the bit-exactness: the decoded answers are also
    // *correct* (fallback jobs exactly, decoded jobs to f32 rounding).
    let set = TaskSet::strassen_winograd(2);
    let got = multiplexed_outputs(&set, 42, 4);
    for ((a, b), c) in job_stream(42).iter().zip(&got) {
        let want = a.matmul(b);
        assert!(c.approx_eq(&want, 1e-3), "rel {}", c.rel_error(&want));
    }
}

#[test]
fn submit_reports_queue_full_at_queue_cap() {
    let cap = 5;
    let mut s = MmServer::new(
        TaskSet::strassen_winograd(2),
        Backend::Native,
        ServerConfig {
            master: MasterConfig {
                deadline: Duration::from_secs(5),
                fault: FaultPlan::NONE,
                seed: 1,
                fallback_local: true,
                collect_all: false,
            },
            queue_cap: cap,
            inflight_depth: 2,
        },
    );
    for i in 0..cap {
        assert_eq!(s.queue_depth(), i);
        s.submit(Matrix::zeros(8, 8), Matrix::zeros(8, 8)).unwrap();
    }
    let err = s.submit(Matrix::zeros(8, 8), Matrix::zeros(8, 8)).unwrap_err();
    assert!(err.contains("queue full"), "{err}");
    assert!(err.contains("5"), "cap should appear in the error: {err}");
    // Completing one job frees exactly one admission slot.
    let done = s.drain(1).unwrap();
    assert_eq!(done.len(), 1);
    s.submit(Matrix::zeros(8, 8), Matrix::zeros(8, 8)).unwrap();
    assert!(s.submit(Matrix::zeros(8, 8), Matrix::zeros(8, 8)).is_err());
    s.shutdown();
}
