//! Property tests for the SIMD microkernel against the scalar packed
//! kernel (itself bit-identical to the naive oracle):
//!
//! * random shapes — elementwise agreement within the documented FMA
//!   bound [`kernel::simd_abs_bound`]: the fused chain rounds once per
//!   step where the scalar chain rounds twice, so low bits may differ
//!   but never by more than the two forward-error cones;
//! * small-integer operands — every product and partial sum is exactly
//!   representable in `f32`, so fused and unfused rounding coincide and
//!   the kernels must agree **bit-for-bit**;
//! * NaN/Inf operands — propagation positions must match the oracle
//!   (FMA changes rounding of finite intermediates only, never which
//!   elements go non-finite);
//! * thread-count invariance (row-panel partitioning never reorders a
//!   per-element accumulation chain);
//! * the `simd` CLI name and the runtime degradation report.
//!
//! On hardware without AVX2+FMA / NEON the SIMD entry points fall back
//! to the scalar microkernel, so every test here still runs — the
//! bound checks simply collapse to exact equality.

use ft_strassen::linalg::kernel::{self, KernelKind};
use ft_strassen::linalg::matrix::Matrix;
use ft_strassen::testkit::{check_panics, gen, PropConfig};

/// Elementwise comparison under the FMA policy: non-finite positions
/// must match exactly, finite values must land within `bound`.
fn assert_close(got: &Matrix, want: &Matrix, bound: f32, what: &str) {
    assert_eq!(got.shape(), want.shape(), "{what}: shape");
    for (i, (x, y)) in got.as_slice().iter().zip(want.as_slice()).enumerate() {
        let ok = if y.is_nan() {
            x.is_nan()
        } else if y.is_infinite() {
            x == y
        } else {
            (x - y).abs() <= bound
        };
        assert!(ok, "{what}: element {i}: got {x}, want {y}, bound {bound}");
    }
}

#[test]
fn prop_simd_matches_scalar_packed_within_the_fma_bound() {
    check_panics(
        "simd ~ packed",
        PropConfig { cases: 60, base_seed: 0x51d0 },
        |rng| {
            let m = gen::size(rng, 1, 80);
            let k = gen::size(rng, 1, 80);
            let n = gen::size(rng, 1, 80);
            // `Matrix::random` draws from (-1, 1), so the documented
            // bound applies with a_max = b_max = 1.
            let a = Matrix::random(m, k, rng);
            let b = Matrix::random(k, n, rng);
            let want = kernel::matmul_packed(&a, &b, 1);
            let got = kernel::matmul_simd(&a, &b, 1);
            let bound = kernel::simd_abs_bound(k, 1.0, 1.0);
            assert_close(&got, &want, bound, &format!("{m}x{k}x{n}"));
        },
    );
}

#[test]
fn prop_simd_is_bit_exact_on_small_integer_operands() {
    check_panics(
        "simd integer-exact",
        PropConfig { cases: 40, base_seed: 0x51d1 },
        |rng| {
            let m = gen::size(rng, 1, 64);
            let k = gen::size(rng, 1, 64);
            let n = gen::size(rng, 1, 64);
            let a = Matrix::from_fn(m, k, |_, _| (rng.below(9) as f32) - 4.0);
            let b = Matrix::from_fn(k, n, |_, _| (rng.below(9) as f32) - 4.0);
            // |dot| <= 64 * 16: exact in f32, so one rounding or two
            // makes no difference and the results must be identical.
            assert_eq!(
                kernel::matmul_simd(&a, &b, 1).as_slice(),
                kernel::matmul_packed(&a, &b, 1).as_slice(),
                "{m}x{k}x{n}"
            );
        },
    );
}

#[test]
fn prop_simd_propagates_nonfinite_like_the_oracle() {
    check_panics(
        "simd NaN/Inf propagation",
        PropConfig { cases: 40, base_seed: 0x51d2 },
        |rng| {
            let m = gen::size(rng, 1, 40);
            let k = gen::size(rng, 2, 40);
            let n = gen::size(rng, 1, 40);
            let mut a = Matrix::random(m, k, rng);
            let mut b = Matrix::random(k, n, rng);
            for _ in 0..4 {
                let (i, j) = (gen::size(rng, 0, m - 1), gen::size(rng, 0, k - 1));
                a[(i, j)] = match rng.below(3) {
                    0 => f32::NAN,
                    1 => f32::INFINITY,
                    _ => 0.0,
                };
                let (p, q) = (gen::size(rng, 0, k - 1), gen::size(rng, 0, n - 1));
                b[(p, q)] = match rng.below(3) {
                    0 => f32::NAN,
                    1 => f32::NEG_INFINITY,
                    _ => 0.0,
                };
            }
            // Elements whose oracle value is finite only ever saw
            // finite terms bounded by 1, so the (k, 1, 1) bound holds.
            let want = a.matmul_naive(&b);
            let bound = kernel::simd_abs_bound(k, 1.0, 1.0);
            assert_close(&kernel::matmul_simd(&a, &b, 1), &want, bound, "simd");
            assert_close(&kernel::matmul_simd(&a, &b, 3), &want, bound, "simd mt");
        },
    );
}

#[test]
fn prop_simd_is_threadcount_invariant() {
    check_panics(
        "simd thread invariance",
        PropConfig { cases: 20, base_seed: 0x51d3 },
        |rng| {
            let m = gen::size(rng, 60, 200);
            let k = gen::size(rng, 1, 90);
            let n = gen::size(rng, 1, 90);
            let a = Matrix::random(m, k, rng);
            let b = Matrix::random(k, n, rng);
            let serial = kernel::matmul_simd(&a, &b, 1);
            for t in [2, 5, 16] {
                assert_eq!(
                    kernel::matmul_simd(&a, &b, t).as_slice(),
                    serial.as_slice(),
                    "{m}x{k}x{n} threads={t}"
                );
            }
        },
    );
}

#[test]
fn simd_into_reuses_a_stale_buffer() {
    let mut rng = ft_strassen::sim::rng::Rng::seeded(7);
    let a = Matrix::random(20, 33, &mut rng);
    let b = Matrix::random(33, 11, &mut rng);
    let want = kernel::matmul_simd(&a, &b, 1);
    let mut out = Matrix::from_fn(50, 50, |i, j| (i + j) as f32);
    kernel::matmul_simd_into(&a, &b, &mut out, 1);
    assert_eq!(out.shape(), (20, 11));
    assert_eq!(out.as_slice(), want.as_slice());
}

#[test]
fn simd_entry_points_bump_a_call_counter() {
    let a = Matrix::from_slice(2, 2, &[1.0, 2.0, 3.0, 4.0]);
    let b = Matrix::from_slice(2, 2, &[5.0, 6.0, 7.0, 8.0]);
    // Counters are process-global and sibling tests also bump them, so
    // only monotone assertions are safe here (exact deltas live in the
    // single-test binary `tests/recursive_arena.rs`).
    let before = kernel::packed_call_count() + kernel::simd_call_count();
    let _ = kernel::matmul_simd(&a, &b, 1);
    let after = kernel::packed_call_count() + kernel::simd_call_count();
    assert!(after > before, "matmul_simd must count one packed-core call");
    if kernel::simd_available() {
        let s0 = kernel::simd_call_count();
        let _ = kernel::matmul_simd(&a, &b, 1);
        assert!(kernel::simd_call_count() > s0, "SIMD hardware must use the SIMD counter");
    }
}

#[test]
fn simd_kind_parses_and_degrades_to_packed_without_cpu_support() {
    assert_eq!(KernelKind::parse("simd").unwrap(), KernelKind::Simd);
    assert_eq!(KernelKind::parse(KernelKind::Simd.display_name()).unwrap(), KernelKind::Simd);
    let eff = kernel::effective_kind(KernelKind::Simd);
    if kernel::simd_available() {
        assert_eq!(eff, KernelKind::Simd);
    } else {
        assert_eq!(eff, KernelKind::Packed, "no CPU support: simd must degrade to packed");
    }
    assert_eq!(kernel::effective_kind(KernelKind::Packed), KernelKind::Packed);
    assert_eq!(kernel::effective_kind(KernelKind::Naive), KernelKind::Naive);
}

#[test]
fn fma_bound_scales_with_reduction_depth_and_magnitudes() {
    assert_eq!(kernel::simd_abs_bound(0, 1.0, 1.0), 0.0);
    let b16 = kernel::simd_abs_bound(16, 1.0, 1.0);
    let b64 = kernel::simd_abs_bound(64, 1.0, 1.0);
    assert!(b16 > 0.0 && b64 > b16, "bound must grow with k: {b16} vs {b64}");
    assert!(kernel::simd_abs_bound(16, 2.0, 3.0) > b16, "bound must grow with magnitudes");
}
