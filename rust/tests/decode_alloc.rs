//! Alloc-regression: the decode path performs **zero matrix clones per
//! solve**. One test function on purpose — `Matrix::clone_count()` is a
//! process-global counter, and a single-test binary keeps the window
//! free of concurrent cloning from sibling tests.

use std::sync::Arc;
use std::time::{Duration, Instant};

use ft_strassen::coding::nested::NestedTaskSet;
use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::coordinator::job::JobState;
use ft_strassen::coordinator::task::{DispatchPlan, NestedGraph, TaskGraph};
use ft_strassen::coordinator::worker::{Backend, WorkerReply};
use ft_strassen::linalg::blocked::{encode_operand, split_blocks};
use ft_strassen::linalg::matrix::Matrix;
use ft_strassen::obs::{RingRecorder, Tracer};
use ft_strassen::sim::rng::Rng;

fn reply(task_id: usize, m: Matrix) -> WorkerReply {
    WorkerReply { job_id: 1, task_id, product: Ok(m), compute_time: Duration::ZERO }
}

fn job(plan: &DispatchPlan, a4: [Matrix; 4], b4: [Matrix; 4], eager: bool) -> JobState {
    let now = Instant::now();
    JobState::new(
        plan,
        1,
        Arc::new(a4),
        Arc::new(b4),
        now,
        now,
        now + Duration::from_secs(5),
        0,
        0,
        eager,
    )
}

#[test]
fn decode_path_performs_zero_matrix_clones_per_solve() {
    let mut rng = Rng::seeded(3);

    // --- flat: feed every reply, then assemble --------------------------
    let graph = TaskGraph::new(TaskSet::strassen_winograd(2));
    let a = Matrix::random(16, 16, &mut rng);
    let b = Matrix::random(16, 16, &mut rng);
    let a4 = split_blocks(&a);
    let b4 = split_blocks(&b);
    let plan = DispatchPlan::Flat(graph.clone());
    let mut flat = job(&plan, a4.clone(), b4.clone(), true);
    let replies: Vec<WorkerReply> = graph
        .specs
        .iter()
        .map(|spec| {
            let p = encode_operand(&spec.int_ca(), &a4)
                .matmul(&encode_operand(&spec.int_cb(), &b4));
            reply(spec.id, p)
        })
        .collect();
    let before = Matrix::clone_count();
    for r in replies {
        flat.on_reply(r);
    }
    assert!(flat.is_decodable());
    let c = flat.assemble(&Backend::Native).unwrap();
    assert_eq!(
        Matrix::clone_count(),
        before,
        "flat reply-folding + solve + assemble must clone no matrices"
    );
    assert!(c.approx_eq(&a.matmul(&b), 1e-4), "rel {}", c.rel_error(&a.matmul(&b)));

    // --- nested (eager): group recoveries + outer solve ------------------
    let ngraph = NestedGraph::new(NestedTaskSet::compose(
        TaskSet::strassen_winograd(0),
        TaskSet::strassen_winograd(0),
    ));
    let n = 8;
    let a = Matrix::from_fn(n, n, |_, _| (rng.below(7) as f32) - 3.0);
    let b = Matrix::from_fn(n, n, |_, _| (rng.below(7) as f32) - 3.0);
    let a4 = split_blocks(&a);
    let b4 = split_blocks(&b);
    let nplan = DispatchPlan::Nested(ngraph.clone());
    let mut nested = job(&nplan, a4.clone(), b4.clone(), true);
    let m2 = ngraph.group_size();
    // Precompute every leaf product exactly as a worker would.
    let make_replies = || {
        let mut v = Vec::new();
        for (g, ospec) in ngraph.outer.specs.iter().enumerate() {
            let lo = encode_operand(&ospec.int_ca(), &a4);
            let ro = encode_operand(&ospec.int_cb(), &b4);
            let lo4 = split_blocks(&lo);
            let ro4 = split_blocks(&ro);
            for (j, ispec) in ngraph.inner.specs.iter().enumerate() {
                let li = encode_operand(&ispec.int_ca(), &lo4);
                let ri = encode_operand(&ispec.int_cb(), &ro4);
                v.push(reply(g * m2 + j, li.matmul(&ri)));
            }
        }
        v
    };
    let before = Matrix::clone_count();
    for r in make_replies() {
        // Late replies for already-recovered groups still fold into the
        // accounting; the returned revocation ranges are queue-side
        // concerns with no queue here.
        let _ = nested.on_reply(r);
    }
    assert!(nested.is_decodable());
    let c = nested.assemble(&Backend::Native).unwrap();
    assert_eq!(
        Matrix::clone_count(),
        before,
        "nested group recovery + outer solve must clone no matrices"
    );
    assert_eq!(c.as_slice(), a.matmul(&b).as_slice(), "integer decode stays exact");

    // --- tracing regression: on or off, spans cost no matrix traffic --
    // Rerun the nested fold with the default off tracer and again with
    // a live ring-buffer tracer installed; both runs must show the
    // exact same clone/alloc deltas over identical work — the "tracing
    // is zero-cost when disabled, and never costs matrix traffic when
    // enabled" contract, pinned at its most alloc-sensitive call site
    // (group recovery inside `on_reply`).
    let want = a.matmul(&b);
    let rerun = |tracer: Tracer| -> (u64, u64) {
        let replies = make_replies();
        let mut j = job(&nplan, a4.clone(), b4.clone(), true);
        j.set_tracer(tracer);
        let before_clones = Matrix::clone_count();
        let before_allocs = Matrix::alloc_count();
        for r in replies {
            let _ = j.on_reply(r);
        }
        assert!(j.is_decodable());
        let c = j.assemble(&Backend::Native).unwrap();
        assert_eq!(c.as_slice(), want.as_slice());
        (Matrix::clone_count() - before_clones, Matrix::alloc_count() - before_allocs)
    };
    let ring = Arc::new(RingRecorder::with_capacity(1 << 12));
    let off = rerun(Tracer::off());
    let on = rerun(Tracer::new(ring.clone()));
    assert_eq!(off.0, 0, "the decode path stays clone-free with tracing off");
    assert_eq!(on, off, "live span emission must add zero matrix clones/allocs");
    assert!(ring.emitted() > 0, "group recoveries must land in the ring");
}
