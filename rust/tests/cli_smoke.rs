//! Launcher smoke tests: run the actual `ft-strassen` binary for every
//! subcommand and check output shape + exit codes (the launcher is the
//! deployment surface, so it gets end-to-end coverage too).

use std::process::Command;

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_ft-strassen"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn ft-strassen");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn no_args_prints_usage() {
    let (stdout, _, ok) = run(&[]);
    assert!(ok);
    assert!(stdout.contains("subcommands:"));
}

#[test]
fn info_lists_all_schemes() {
    let (stdout, _, ok) = run(&["info"]);
    assert!(ok, "{stdout}");
    for s in ["strassen x1", "strassen x2", "strassen x3", "S+W +0 PSMM", "S+W +2 PSMM"] {
        assert!(stdout.contains(s), "missing {s} in:\n{stdout}");
    }
    assert!(stdout.contains("C11"));
}

#[test]
fn fc_prints_first_loss_structure() {
    let (stdout, _, ok) = run(&["fc"]);
    assert!(ok);
    // S+W+2PSMM must start failing at k=3 with 9 combinations.
    assert!(stdout.contains("k=3:9"), "{stdout}");
    // 3-copy: k=3:7.
    assert!(stdout.contains("k=3:7"), "{stdout}");
}

#[test]
fn theory_emits_table() {
    let (stdout, _, ok) = run(&["theory", "--points", "3"]);
    assert!(ok);
    assert!(stdout.contains("p_e"));
    assert!(stdout.lines().count() >= 4, "{stdout}");
}

#[test]
fn sim_crosschecks_theory() {
    let (stdout, _, ok) = run(&["sim", "--p-e", "0.1", "--trials", "20000"]);
    assert!(ok);
    assert!(stdout.contains("theory="), "{stdout}");
    assert!(stdout.contains("mc="), "{stdout}");
}

#[test]
fn search_prints_relations_and_psmms() {
    let (stdout, _, ok) = run(&["search", "--max-k", "6"]);
    assert!(ok);
    assert!(stdout.contains("C21 = S2 + S4"), "{stdout}");
    assert!(stdout.contains("P1 ="), "{stdout}");
    assert!(stdout.contains("P2 ="), "{stdout}");
}

#[test]
fn multiply_native_reports_exactness() {
    let (stdout, _, ok) = run(&[
        "multiply", "--n", "64", "--scheme", "sw+2psmm", "--backend", "native",
        "--p-e", "0.1", "--seed", "3",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("rel_error"), "{stdout}");
    // decode or fallback — either way the answer is checked tiny:
    let err_line = stdout.lines().find(|l| l.contains("rel_error")).unwrap();
    let v: f64 = err_line.rsplit('=').next().unwrap().trim().parse().unwrap();
    assert!(v < 1e-3, "rel error {v}");
}

#[test]
fn serve_native_runs_workload() {
    let (stdout, _, ok) = run(&[
        "serve", "--jobs", "4", "--n", "32", "--scheme", "sw+1psmm",
        "--backend", "native", "--p-straggle", "0.2",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("jobs/s"), "{stdout}");
    assert!(stdout.contains("decoded="), "{stdout}");
}

#[test]
fn nested_curves_smoke() {
    let (stdout, _, ok) = run(&["nested", "--trials", "2000", "--points", "3"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("sw+2psmm:sw+2psmm"), "{stdout}");
    assert!(stdout.contains("leaves=256"), "{stdout}");
    assert!(stdout.contains("first fatal k=9"), "{stdout}");
    assert!(stdout.contains("nested_curves.csv"), "{stdout}");
}

#[test]
fn multiply_nested_dispatches_256_leaves() {
    let (stdout, _, ok) = run(&[
        "multiply", "--n", "32", "--nest", "sw+2psmm:sw+2psmm",
        "--backend", "native", "--p-e", "0.05", "--seed", "5",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("tasks=256"), "{stdout}");
    assert!(stdout.contains("scheme=S+W +2 PSMM:S+W +2 PSMM"), "{stdout}");
    let err_line = stdout.lines().find(|l| l.contains("rel_error")).unwrap();
    let v: f64 = err_line.rsplit('=').next().unwrap().trim().parse().unwrap();
    assert!(v < 1e-3, "rel error {v}");
}

#[test]
fn serve_nested_runs_workload() {
    let (stdout, _, ok) = run(&[
        "serve", "--jobs", "3", "--n", "16", "--nest", "sw+0psmm:sw+0psmm",
        "--backend", "native", "--workers", "14", "--depth", "2",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("scheme=sw+0psmm:sw+0psmm"), "{stdout}");
    assert!(stdout.contains("jobs/s"), "{stdout}");
}

#[test]
fn nested_rejects_bad_dimension() {
    let (_, stderr, ok) = run(&[
        "multiply", "--n", "6", "--nest", "sw+0psmm:sw+0psmm", "--backend", "native",
    ]);
    assert!(!ok);
    assert!(stderr.contains("divisible by 4"), "{stderr}");
}

#[test]
fn config_file_is_honored_and_cli_overrides() {
    let (stdout, _, ok) = run(&[
        "serve", "--config", "configs/sim_fig2.toml", "--jobs", "2",
        "--backend", "native", "--n", "16",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("scheme=sw+2psmm"), "{stdout}");
    assert!(stdout.contains("n=16"), "{stdout}");
}

#[test]
fn localmm_times_flat_against_recursive() {
    let (stdout, _, ok) = run(&[
        "localmm", "--n", "96", "--kernel", "simd", "--cutoff", "32", "--max-depth", "2",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("localmm n=96"), "{stdout}");
    assert!(stdout.contains("cutoff=32 max_depth=2"), "{stdout}");
    assert!(stdout.contains("speedup=x"), "{stdout}");
    let err_line = stdout.lines().find(|l| l.contains("rel_error")).unwrap();
    let v: f64 = err_line.rsplit('=').next().unwrap().trim().parse().unwrap();
    assert!(v < 1e-3, "rel error {v}");
}

#[test]
fn localmm_covers_the_kernel_by_cutoff_matrix() {
    // Every kernel route × two cutoffs must run, echo its configuration,
    // and agree with the flat product (simd silently falls back to the
    // scalar packed kernel off-AVX2 — the exit code and the check hold
    // either way).
    for kernel in ["naive", "packed", "simd"] {
        for cutoff in ["16", "48"] {
            let (stdout, stderr, ok) = run(&[
                "localmm", "--n", "64", "--kernel", kernel, "--cutoff", cutoff,
            ]);
            assert!(ok, "kernel={kernel} cutoff={cutoff}:\n{stdout}\n{stderr}");
            assert!(
                stdout.contains(&format!("kernel={kernel}")),
                "kernel={kernel} cutoff={cutoff}:\n{stdout}"
            );
            assert!(
                stdout.contains(&format!("cutoff={cutoff}")),
                "kernel={kernel} cutoff={cutoff}:\n{stdout}"
            );
            let err_line = stdout.lines().find(|l| l.contains("rel_error")).unwrap();
            let v: f64 = err_line.rsplit('=').next().unwrap().trim().parse().unwrap();
            assert!(v < 1e-3, "kernel={kernel} cutoff={cutoff}: rel error {v}");
        }
    }
}

#[test]
fn localmm_depth_zero_means_unlimited() {
    // `--max-depth 0` is the config sentinel for "no depth cap".
    let (stdout, _, ok) = run(&[
        "localmm", "--n", "64", "--kernel", "packed", "--cutoff", "16", "--max-depth", "0",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("max_depth=unlimited"), "{stdout}");
}

#[test]
fn nested_multiply_covers_the_kernel_by_cutoff_matrix() {
    // The nested dispatch path under each kernel route (the kernel flag
    // is process-wide, so every worker product takes it) with an
    // explicit cutoff: 196 leaves, tiny reconstruction error each time.
    for kernel in ["naive", "packed", "simd"] {
        let (stdout, stderr, ok) = run(&[
            "multiply", "--n", "16", "--nest", "sw+0psmm:sw+0psmm",
            "--backend", "native", "--kernel", kernel, "--cutoff", "32", "--seed", "7",
        ]);
        assert!(ok, "kernel={kernel}:\n{stdout}\n{stderr}");
        assert!(stdout.contains("tasks=196"), "kernel={kernel}:\n{stdout}");
        let err_line = stdout.lines().find(|l| l.contains("rel_error")).unwrap();
        let v: f64 = err_line.rsplit('=').next().unwrap().trim().parse().unwrap();
        assert!(v < 1e-3, "kernel={kernel}: rel error {v}");
    }
}

#[test]
fn nested_curves_accept_kernel_and_cutoff_flags() {
    // The `nested` curves subcommand is simulation-only, but the shared
    // flag surface must stay accepted (config parsing is common to all
    // subcommands) without changing its output shape.
    let (stdout, _, ok) = run(&[
        "nested", "--trials", "1000", "--points", "2", "--kernel", "packed", "--cutoff", "32",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("sw+0psmm:sw+0psmm"), "{stdout}");
    assert!(stdout.contains("first fatal k="), "{stdout}");
}

#[test]
fn localmm_rejects_zero_cutoff() {
    let (_, stderr, ok) = run(&["localmm", "--n", "16", "--cutoff", "0"]);
    assert!(!ok);
    assert!(stderr.contains("cutoff"), "{stderr}");
}

#[test]
fn simfleet_campaign_agrees_with_nested_theory() {
    let (stdout, stderr, ok) = run(&[
        "simfleet", "--workers", "300", "--jobs", "30", "--points", "3",
        "--policies", "random,fastest",
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    assert!(stdout.contains("simfleet: "), "{stdout}");
    assert!(stdout.contains("256 leaves/job"), "{stdout}");
    assert!(stdout.contains("policy random:"), "{stdout}");
    assert!(stdout.contains("policy fastest:"), "{stdout}");
    assert!(stdout.contains("trace_digest="), "{stdout}");
    assert!(stdout.contains("all sweep points agree"), "{stdout}");
}

#[test]
fn simfleet_output_is_deterministic_run_to_run() {
    // The campaign report contains only simulated time and digests —
    // no wall clock — so the same seed + config must print the same
    // bytes on every run, on any machine.
    let args = [
        "simfleet", "--workers", "200", "--jobs", "20", "--pe-sweep", "0.3",
        "--policies", "speculative", "--arrival", "poisson:400",
    ];
    let (first, _, ok1) = run(&args);
    let (second, _, ok2) = run(&args);
    assert!(ok1 && ok2, "{first}");
    assert_eq!(first, second, "simfleet output changed between identical runs");
}

#[test]
fn simfleet_rejects_unknown_policy() {
    let (_, stderr, ok) = run(&["simfleet", "--policies", "bogus", "--jobs", "4"]);
    assert!(!ok);
    assert!(stderr.contains("unknown policy"), "{stderr}");
}

#[test]
fn simfleet_honors_fleet_config_overrides() {
    let (stdout, stderr, ok) = run(&[
        "simfleet", "--workers", "128", "--jobs", "8", "--pe-sweep", "0.4",
        "--rack-size", "64", "--policies", "locality",
        "--leaf-latency", "sexp:0.005:100",
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    assert!(stdout.contains("128 workers in 2 racks"), "{stdout}");
    assert!(stdout.contains("policy locality:"), "{stdout}");
}

#[test]
fn bad_scheme_fails_with_message() {
    let (_, stderr, ok) = run(&["multiply", "--scheme", "bogus"]);
    assert!(!ok);
    assert!(stderr.contains("unknown scheme"), "{stderr}");
}

#[test]
fn bad_option_fails_with_usage() {
    let (_, stderr, ok) = run(&["sim", "--trials"]);
    assert!(!ok);
    assert!(stderr.contains("expects a value"), "{stderr}");
}