//! Full-stack integration: the rust coordinator driving the AOT Pallas
//! artifacts through PJRT (when `artifacts/` exists — run
//! `make artifacts`), cross-checked against the native backend and dense
//! ground truth. These are the "all layers compose" tests of
//! EXPERIMENTS.md's e2e row.

use std::path::Path;
use std::time::Duration;

use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::coordinator::master::{Master, MasterConfig};
use ft_strassen::coordinator::server::{MmServer, ServerConfig};
use ft_strassen::coordinator::worker::{Backend, FaultPlan};
use ft_strassen::linalg::matrix::Matrix;
use ft_strassen::runtime::service::ComputeService;
use ft_strassen::sim::rng::Rng;

fn pjrt_backend(bs: usize) -> Option<(Backend, ComputeService)> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let svc = ComputeService::spawn(&dir, &[bs]).ok()?;
    Some((Backend::Pjrt(svc.handle()), svc))
}

#[test]
fn pjrt_multiply_matches_dense_no_faults() {
    let Some((backend, _svc)) = pjrt_backend(64) else { return };
    let mut master = Master::new(
        TaskSet::strassen_winograd(2),
        backend,
        MasterConfig {
            deadline: Duration::from_secs(30),
            fault: FaultPlan::NONE,
            seed: 1,
            fallback_local: false,
            collect_all: false,
        },
    );
    let mut rng = Rng::seeded(11);
    let a = Matrix::random(128, 128, &mut rng);
    let b = Matrix::random(128, 128, &mut rng);
    let (c, report) = master.multiply(&a, &b).unwrap();
    let want = a.matmul(&b);
    assert!(!report.fell_back);
    assert!(
        c.approx_eq(&want, 1e-3),
        "pjrt rel err {}",
        c.rel_error(&want)
    );
    master.shutdown();
}

#[test]
fn pjrt_multiply_survives_failures_and_stragglers() {
    let Some((backend, _svc)) = pjrt_backend(32) else { return };
    let mut master = Master::new(
        TaskSet::strassen_winograd(2),
        backend,
        MasterConfig {
            deadline: Duration::from_secs(30),
            fault: FaultPlan {
                p_fail: 0.12,
                p_straggle: 0.2,
                delay: Duration::from_millis(50),
            },
            seed: 5,
            fallback_local: true,
            collect_all: false,
        },
    );
    let mut rng = Rng::seeded(13);
    let a = Matrix::random(64, 64, &mut rng);
    let b = Matrix::random(64, 64, &mut rng);
    let want = a.matmul(&b);
    let mut decoded = 0;
    for _ in 0..6 {
        let (c, report) = master.multiply(&a, &b).unwrap();
        assert!(c.approx_eq(&want, 1e-3), "rel {}", c.rel_error(&want));
        decoded += (!report.fell_back) as u32;
    }
    assert!(decoded >= 4, "only {decoded}/6 jobs decoded");
    master.shutdown();
}

#[test]
fn pjrt_and_native_agree_bitwise_closely() {
    let Some((backend, _svc)) = pjrt_backend(32) else { return };
    let mut rng = Rng::seeded(17);
    let a = Matrix::random(64, 64, &mut rng);
    let b = Matrix::random(64, 64, &mut rng);
    let cfg = MasterConfig {
        deadline: Duration::from_secs(30),
        fault: FaultPlan::NONE,
        seed: 2,
        fallback_local: false,
        collect_all: false,
    };
    let mut mp = Master::new(TaskSet::strassen_winograd(0), backend, cfg.clone());
    let mut mn = Master::new(TaskSet::strassen_winograd(0), Backend::Native, cfg);
    let (cp, _) = mp.multiply(&a, &b).unwrap();
    let (cn, _) = mn.multiply(&a, &b).unwrap();
    // Same bilinear decode, different matmul engine: f32 rounding only.
    assert!(cp.approx_eq(&cn, 1e-4), "rel {}", cp.rel_error(&cn));
    mp.shutdown();
    mn.shutdown();
}

#[test]
fn e2e_server_workload_on_pjrt() {
    let Some((backend, _svc)) = pjrt_backend(64) else { return };
    let mut server = MmServer::new(
        TaskSet::strassen_winograd(2),
        backend,
        ServerConfig {
            master: MasterConfig {
                deadline: Duration::from_secs(30),
                fault: FaultPlan {
                    p_fail: 0.05,
                    p_straggle: 0.1,
                    delay: Duration::from_millis(20),
                },
                seed: 3,
                fallback_local: true,
                collect_all: false,
            },
            queue_cap: 64,
            inflight_depth: 4,
        },
    );
    let report = server.run_workload(6, 128, 23).unwrap();
    assert_eq!(report.jobs, 6);
    assert!(report.decoded >= 4, "decoded {}/6", report.decoded);
    assert!(report.throughput_jobs_per_s > 0.0);
    server.shutdown();
}

#[test]
fn pjrt_missing_block_size_degrades_to_fallback() {
    // n = 48 -> bs = 24: no artifact exists for that block size, so every
    // worker errors out. The master must treat backend errors as node
    // failures and produce the correct answer via local fallback.
    let Some((backend, _svc)) = pjrt_backend(32) else { return };
    let mut master = Master::new(
        TaskSet::strassen_winograd(2),
        backend,
        MasterConfig {
            deadline: Duration::from_secs(5),
            fault: FaultPlan::NONE,
            seed: 1,
            fallback_local: true,
            collect_all: false,
        },
    );
    let mut rng = Rng::seeded(41);
    let a = Matrix::random(48, 48, &mut rng);
    let b = Matrix::random(48, 48, &mut rng);
    let (c, report) = master.multiply(&a, &b).unwrap();
    assert!(report.fell_back, "no artifacts for bs=24 -> fallback");
    assert_eq!(report.finished, 0);
    assert!(c.approx_eq(&a.matmul(&b), 1e-4));
    master.shutdown();
}

#[test]
fn native_full_pipeline_large() {
    // Hermetic large-ish e2e on the native backend (always runs).
    let mut master = Master::new(
        TaskSet::strassen_winograd(1),
        Backend::Native,
        MasterConfig {
            deadline: Duration::from_secs(30),
            fault: FaultPlan {
                p_fail: 0.06,
                p_straggle: 0.0,
                delay: Duration::ZERO,
            },
            seed: 9,
            fallback_local: true,
            collect_all: false,
        },
    );
    let mut rng = Rng::seeded(31);
    let a = Matrix::random(512, 512, &mut rng);
    let b = Matrix::random(512, 512, &mut rng);
    let (c, _) = master.multiply(&a, &b).unwrap();
    let want = a.matmul(&b);
    assert!(c.approx_eq(&want, 1e-3), "rel {}", c.rel_error(&want));
    master.shutdown();
}
