//! End-to-end observability invariants, pinned against live serving
//! runs (real worker threads, real timing):
//!
//! * **Span tree** — every admitted job reaches exactly one terminal,
//!   every leaf lifecycle is well-formed, and for race-free seeded
//!   configs the strict form holds (each dispatched leaf terminates
//!   exactly once).
//! * **Determinism** — two independent seeded runs of the same config
//!   produce byte-identical logical-trace digests, which is what lets
//!   the `trace` CLI subcommand replay a `serve` run.
//! * **Counters == events** — the tier's `replies_stale_dropped` and
//!   `pool_items_revoked` counters equal the number of matching trace
//!   events in the same run, at both purge sites (central dispatch
//!   queue and executed-but-stale replies).
//! * **Cache** — a cache-hit admission emits `cache-hit` spans and
//!   skips the coordinator's bulk encode span.
//! * **Chrome export** — every leaf span of a multi-tenant nested run
//!   sits inside its job's span on the job's track (Chrome's
//!   containment rule then nests them).

use std::sync::Arc;
use std::time::{Duration, Instant};

use ft_strassen::bench::schema::{parse_json, Json};
use ft_strassen::coding::nested::NestedTaskSet;
use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::coordinator::master::MasterConfig;
use ft_strassen::coordinator::server::MmServer;
use ft_strassen::coordinator::task::DispatchPlan;
use ft_strassen::coordinator::tier::{names, ServingTier, TenantSpec, TierConfig};
use ft_strassen::coordinator::worker::{Backend, FaultAction, FaultPlan};
use ft_strassen::linalg::matrix::Matrix;
use ft_strassen::obs::{
    check_span_tree, chrome_trace_json, logical_digest, EventKind, RingRecorder, TraceEvent,
    Tracer, NO_LEAF,
};
use ft_strassen::sim::rng::Rng;

/// Race-free policy: no injected faults, a deadline far beyond test
/// runtime, and `collect_all` so the decode set (and therefore the
/// logical event multiset) is a pure function of `(seed, config)`.
fn race_free(seed: u64) -> MasterConfig {
    MasterConfig {
        deadline: Duration::from_secs(30),
        fault: FaultPlan::NONE,
        seed,
        fallback_local: true,
        collect_all: true,
    }
}

fn tier_cfg(master: MasterConfig, tenants: Vec<TenantSpec>, cache_cap: usize) -> TierConfig {
    TierConfig { master, depth: 4, queue_cap: 64, tenants, batch_window: 1, cache_cap }
}

fn traced_tier(
    plan: DispatchPlan,
    cfg: TierConfig,
    workers: Option<usize>,
) -> (ServingTier, Arc<RingRecorder>) {
    let ring = Arc::new(RingRecorder::with_capacity(1 << 14));
    let tracer = Tracer::new(ring.clone());
    (ServingTier::with_plan_traced(plan, Backend::Native, cfg, workers, tracer), ring)
}

fn count(events: &[TraceEvent], kind: EventKind) -> usize {
    events.iter().filter(|e| e.kind == kind).count()
}

#[test]
fn nested_multi_tenant_run_yields_a_valid_span_tree() {
    let plan = DispatchPlan::nested(NestedTaskSet::compose(
        TaskSet::strassen_winograd(0),
        TaskSet::strassen_winograd(0),
    ));
    let tenants = vec![TenantSpec::new("heavy", 3, 8), TenantSpec::new("light", 1, 8)];
    let (mut tier, ring) = traced_tier(plan, tier_cfg(race_free(7), tenants, 0), Some(6));
    let mut rng = Rng::seeded(7);
    for i in 0..4 {
        let a = Matrix::random(8, 8, &mut rng);
        let b = Matrix::random(8, 8, &mut rng);
        tier.submit(if i % 2 == 0 { "heavy" } else { "light" }, a, b).unwrap();
    }
    let done = tier.drive(4);
    assert_eq!(done.len(), 4);
    tier.shutdown();

    let events = ring.drain();
    assert_eq!(ring.dropped(), 0, "ring must not wrap in a 4-job run");
    let sum = check_span_tree(&events, false).expect("span tree must validate");
    assert_eq!(sum.jobs, 4);
    assert_eq!(sum.decoded, 4, "race-free run must decode every job");
    assert_eq!(sum.failed, 0);
    assert!(sum.dispatched_leaves > 0);
    // Every job recovers its outer groups (detail = group index), and
    // group recoveries are tagged to the owning job's span.
    for job in 1..=4u64 {
        let recovered = events
            .iter()
            .filter(|e| e.kind == EventKind::GroupRecover && e.job == job)
            .count();
        assert!(recovered > 0, "job {job} recovered no groups");
    }
}

#[test]
fn seeded_replays_share_a_logical_digest() {
    // The `trace` subcommand's contract: rebuilding the same seeded
    // serve configuration and re-running it reproduces the logical
    // trace digest byte-for-byte. Pin it at the library layer with two
    // independent servers (fresh fleets, fresh rings).
    let run = || {
        let ring = Arc::new(RingRecorder::with_capacity(1 << 14));
        let tracer = Tracer::new(ring.clone());
        let mut server = MmServer::with_tier_config_traced(
            DispatchPlan::flat(TaskSet::strassen_winograd(2)),
            Backend::Native,
            tier_cfg(race_free(42), vec![TenantSpec::unbounded("default")], 0),
            None,
            tracer,
        );
        let report = server.run_workload(6, 16, 42).unwrap();
        assert_eq!(report.decoded, 6);
        server.shutdown();
        let events = ring.drain();
        assert_eq!(ring.dropped(), 0);
        (logical_digest(&events), check_span_tree(&events, true).unwrap())
    };
    let (d1, s1) = run();
    let (d2, s2) = run();
    assert_eq!(s1.jobs, 6, "the trace must cover every submitted job");
    assert_eq!(d1, d2, "seeded replays must share the logical digest");
    assert_eq!(s1, s2, "seeded replays must share the span summary");
}

#[test]
fn drop_and_revoke_counters_match_their_trace_events() {
    // Site 1: central-dispatch-queue purge. Zero workers, so every
    // admitted leaf sits in the queue when the cancel lands — all of
    // them must be revoked, and each revocation must carry an event.
    let (mut tier, ring) = traced_tier(
        DispatchPlan::flat(TaskSet::strassen_winograd(2)),
        tier_cfg(race_free(1), vec![TenantSpec::unbounded("default")], 0),
        Some(0),
    );
    let j = tier.submit("default", Matrix::zeros(16, 16), Matrix::zeros(16, 16)).unwrap();
    assert!(tier.cancel(j));
    let revoke_counter = tier.metrics.counter(names::POOL_ITEMS_REVOKED).get();
    tier.shutdown();
    let events = ring.drain();
    assert_eq!(revoke_counter, 16, "all 16 queued items revoke on cancel");
    assert_eq!(
        revoke_counter as usize,
        count(&events, EventKind::Revoke),
        "every queue-purge revocation must carry a trace event"
    );

    // Site 2: stale replies. Every item of job 1 rides a delay line;
    // once all are executed the cancel can purge nothing — each of the
    // 16 replies must then land as a counted, traced stale drop.
    let (mut tier, ring) = traced_tier(
        DispatchPlan::flat(TaskSet::strassen_winograd(2)),
        tier_cfg(race_free(1), vec![TenantSpec::unbounded("default")], 0),
        None,
    );
    let (a, b) = {
        let mut rng = Rng::seeded(3);
        (Matrix::random(16, 16, &mut rng), Matrix::random(16, 16, &mut rng))
    };
    let j1 = tier
        .submit_with_faults(
            "default",
            a.clone(),
            b.clone(),
            vec![FaultAction::Delay(Duration::from_millis(400)); 16],
        )
        .unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    while tier.metrics.counter(names::POOL_ITEMS_EXECUTED).get() < 16 {
        assert!(Instant::now() < deadline, "workers never picked up the items");
        tier.poll(Duration::from_millis(20), usize::MAX);
    }
    assert!(tier.cancel(j1));
    tier.submit_with_faults(
        "default",
        a,
        b,
        vec![FaultAction::Delay(Duration::from_millis(800)); 16],
    )
    .unwrap();
    let done = tier.drive(1);
    assert_eq!(done.len(), 1);
    let stale_counter = tier.metrics.counter(names::REPLIES_STALE_DROPPED).get();
    let revoke_counter = tier.metrics.counter(names::POOL_ITEMS_REVOKED).get();
    tier.shutdown();
    let events = ring.drain();
    assert_eq!(
        stale_counter as usize,
        count(&events, EventKind::StaleDrop),
        "every counted stale drop must carry a trace event"
    );
    assert_eq!(stale_counter, 16, "all 16 cancelled-job replies land stale");
    assert_eq!(
        revoke_counter as usize,
        count(&events, EventKind::Revoke),
        "every counted revocation must carry a trace event"
    );
}

#[test]
fn cache_hit_admission_skips_the_bulk_encode_span() {
    let (mut tier, ring) = traced_tier(
        DispatchPlan::flat(TaskSet::strassen_winograd(2)),
        TierConfig {
            master: race_free(5),
            depth: 1, // serialize: job 1 fills the cache before job 2 admits
            queue_cap: 64,
            tenants: vec![TenantSpec::unbounded("default")],
            batch_window: 1,
            cache_cap: 4,
        },
        None,
    );
    let mut rng = Rng::seeded(5);
    let a = Matrix::random(16, 16, &mut rng);
    let b = Matrix::random(16, 16, &mut rng);
    tier.submit("default", a.clone(), b.clone()).unwrap();
    tier.submit("default", a, b).unwrap();
    let done = tier.drive(2);
    assert_eq!(done.len(), 2);
    let hits = tier.metrics.counter(names::CACHE_HITS).get();
    tier.shutdown();

    assert_eq!(hits, 1, "identical left operand must hit the cache once");
    let events = ring.drain();
    // Strict span tree: flat plan, no faults, no cancellation.
    let sum = check_span_tree(&events, true).expect("strict span tree must validate");
    assert_eq!(sum.jobs, 2);
    assert_eq!(sum.cache_hits, 16, "one cache-hit span per leaf of job 2");
    let bulk_encodes = |job: u64| {
        events
            .iter()
            .filter(|e| e.kind == EventKind::Encode && e.job == job && e.leaf == NO_LEAF)
            .count()
    };
    assert_eq!(bulk_encodes(1), 1, "job 1 misses: one coordinator bulk encode");
    assert_eq!(bulk_encodes(2), 0, "job 2 hits: the bulk encode span is skipped");
    assert!(
        !events.iter().any(|e| e.kind == EventKind::CacheHit && e.job == 1),
        "the cold job must not record cache hits"
    );
}

#[test]
fn chrome_export_parents_every_leaf_span_under_its_job_span() {
    let plan = DispatchPlan::nested(NestedTaskSet::compose(
        TaskSet::strassen_winograd(0),
        TaskSet::strassen_winograd(0),
    ));
    let tenants = vec![TenantSpec::new("heavy", 3, 8), TenantSpec::new("light", 1, 8)];
    let (mut tier, ring) = traced_tier(plan, tier_cfg(race_free(9), tenants, 0), Some(4));
    let mut rng = Rng::seeded(9);
    for i in 0..3 {
        let a = Matrix::random(8, 8, &mut rng);
        let b = Matrix::random(8, 8, &mut rng);
        tier.submit(if i % 2 == 0 { "heavy" } else { "light" }, a, b).unwrap();
    }
    let done = tier.drive(3);
    assert_eq!(done.len(), 3);
    tier.shutdown();

    let json = chrome_trace_json(&ring.drain(), "obs-test");
    let doc = parse_json(&json).expect("exporter must emit valid JSON");
    let trace_events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("top-level traceEvents array");
    // Collect job spans as tid -> [start, end], then check every leaf
    // span lies inside its track's job span.
    let span = |e: &Json| -> Option<(u64, f64, f64)> {
        let tid = e.get("tid")?.as_num()? as u64;
        let ts = e.get("ts")?.as_num()?;
        let dur = e.get("dur")?.as_num()?;
        Some((tid, ts, ts + dur))
    };
    let cat_of = |e: &Json| match e.get("cat") {
        Some(Json::Str(s)) => s.clone(),
        _ => String::new(),
    };
    let mut job_spans = std::collections::HashMap::new();
    let mut leaves = 0usize;
    for e in trace_events {
        if cat_of(e) == "job" {
            let (tid, lo, hi) = span(e).expect("job span fields");
            job_spans.insert(tid, (lo, hi));
        }
    }
    assert_eq!(job_spans.len(), 3, "one job span per submitted job");
    for e in trace_events {
        if cat_of(e) == "leaf" {
            leaves += 1;
            let (tid, lo, hi) = span(e).expect("leaf span fields");
            let &(jlo, jhi) = job_spans
                .get(&tid)
                .unwrap_or_else(|| panic!("leaf span on track {tid} with no job span"));
            assert!(
                jlo <= lo && hi <= jhi,
                "leaf span [{lo}, {hi}] escapes job span [{jlo}, {jhi}] on track {tid}"
            );
        }
    }
    assert!(leaves > 0, "the export must draw leaf spans");
}
