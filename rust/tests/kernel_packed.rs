//! Property tests pitting the packed kernel against the naive oracle:
//!
//! * random shapes, including non-square, non-divisible-by-anything and
//!   degenerate 1×N / N×1 — results must be **bit-identical** (both
//!   kernels accumulate each element in ascending-k order and Rust
//!   never contracts to FMA);
//! * NaN/Inf operands — IEEE propagation must match the oracle, and a
//!   zero lhs coefficient must NOT launder a non-finite rhs row (the
//!   old kernel's zero-skip bug);
//! * thread-count invariance of the parallel row-panel loop;
//! * the `Matrix::matmul` dispatch path agreeing with both.

use ft_strassen::linalg::kernel::{self, KernelKind};
use ft_strassen::linalg::matrix::Matrix;
use ft_strassen::testkit::{check_panics, gen, PropConfig};

/// Bit-level equality with NaN == NaN (propagation positions must
/// match; on one platform the same op sequence yields the same bits).
fn assert_same(got: &Matrix, want: &Matrix, what: &str) {
    assert_eq!(got.shape(), want.shape(), "{what}: shape");
    for (i, (x, y)) in got
        .as_slice()
        .iter()
        .zip(want.as_slice().iter())
        .enumerate()
    {
        let same = (x.is_nan() && y.is_nan()) || x == y;
        assert!(same, "{what}: element {i}: got {x}, want {y}");
    }
}

#[test]
fn prop_packed_matches_naive_on_random_shapes() {
    check_panics(
        "packed == naive",
        PropConfig { cases: 60, base_seed: 0x7ac },
        |rng| {
            let m = gen::size(rng, 1, 80);
            let k = gen::size(rng, 1, 80);
            let n = gen::size(rng, 1, 80);
            let a = Matrix::random(m, k, rng);
            let b = Matrix::random(k, n, rng);
            let want = a.matmul_naive(&b);
            let got = kernel::matmul_packed(&a, &b, 1);
            assert_eq!(got.as_slice(), want.as_slice(), "{m}x{k}x{n}");
        },
    );
}

#[test]
fn prop_packed_matches_naive_on_degenerate_shapes() {
    check_panics(
        "degenerate shapes",
        PropConfig { cases: 40, base_seed: 0x7ad },
        |rng| {
            // 1×N, N×1 and single-k shapes hit every panel-tail branch.
            let n = gen::size(rng, 1, 130);
            let shapes = [(1, n, n), (n, n, 1), (n, 1, n), (1, 1, n), (n, 1, 1)];
            for (m, k, cols) in shapes {
                let a = Matrix::random(m, k, rng);
                let b = Matrix::random(k, cols, rng);
                assert_eq!(
                    kernel::matmul_packed(&a, &b, 1).as_slice(),
                    a.matmul_naive(&b).as_slice(),
                    "{m}x{k}x{cols}"
                );
            }
        },
    );
}

#[test]
fn prop_packed_matches_naive_on_nonfinite_operands() {
    check_panics(
        "NaN/Inf propagation",
        PropConfig { cases: 40, base_seed: 0x7ae },
        |rng| {
            let m = gen::size(rng, 1, 40);
            let k = gen::size(rng, 2, 40);
            let n = gen::size(rng, 1, 40);
            let mut a = Matrix::random(m, k, rng);
            let mut b = Matrix::random(k, n, rng);
            // Sprinkle non-finite values and exact zeros (the zero-skip
            // regression needs a zero lhs entry meeting a NaN rhs row).
            for _ in 0..4 {
                let (i, j) = (gen::size(rng, 0, m - 1), gen::size(rng, 0, k - 1));
                a[(i, j)] = match rng.below(3) {
                    0 => f32::NAN,
                    1 => f32::INFINITY,
                    _ => 0.0,
                };
                let (p, q) = (gen::size(rng, 0, k - 1), gen::size(rng, 0, n - 1));
                b[(p, q)] = match rng.below(3) {
                    0 => f32::NAN,
                    1 => f32::NEG_INFINITY,
                    _ => 0.0,
                };
            }
            let want = a.matmul_naive(&b);
            assert_same(&kernel::matmul_packed(&a, &b, 1), &want, "packed");
            assert_same(&kernel::matmul_packed(&a, &b, 3), &want, "packed mt");
        },
    );
}

#[test]
fn zero_times_nonfinite_is_not_skipped() {
    // The documented zero-skip regression, end to end through dispatch:
    // lhs [0, 1] · rhs [[NaN, Inf], [1, 1]] must be [NaN, NaN].
    let a = Matrix::from_slice(1, 2, &[0.0, 1.0]);
    let b = Matrix::from_slice(2, 2, &[f32::NAN, f32::INFINITY, 1.0, 1.0]);
    for (what, c) in [
        ("dispatch", a.matmul(&b)),
        ("naive", a.matmul_naive(&b)),
        ("packed", kernel::matmul_packed(&a, &b, 1)),
    ] {
        assert!(c[(0, 0)].is_nan(), "{what}: 0·NaN must poison");
        assert!(c[(0, 1)].is_nan(), "{what}: 0·Inf must poison");
    }
}

#[test]
fn prop_parallel_is_threadcount_invariant() {
    check_panics(
        "thread invariance",
        PropConfig { cases: 20, base_seed: 0x7af },
        |rng| {
            let m = gen::size(rng, 60, 200);
            let k = gen::size(rng, 1, 90);
            let n = gen::size(rng, 1, 90);
            let a = Matrix::random(m, k, rng);
            let b = Matrix::random(k, n, rng);
            let serial = kernel::matmul_packed(&a, &b, 1);
            for t in [2, 5, 16] {
                assert_eq!(
                    kernel::matmul_packed(&a, &b, t).as_slice(),
                    serial.as_slice(),
                    "{m}x{k}x{n} threads={t}"
                );
            }
        },
    );
}

#[test]
fn dispatch_agrees_with_both_kernels_across_the_threshold() {
    // Under and over PACKED_MIN_FLOPS the dispatched result equals both
    // kernels bitwise, whatever the heuristic picked.
    let mut rng = ft_strassen::sim::rng::Rng::seeded(99);
    for n in [8usize, 32, 64, 96] {
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        let via_dispatch = a.matmul(&b);
        assert_eq!(via_dispatch.as_slice(), a.matmul_naive(&b).as_slice(), "n={n}");
        assert_eq!(
            via_dispatch.as_slice(),
            kernel::matmul_packed(&a, &b, 1).as_slice(),
            "n={n}"
        );
    }
}

#[test]
fn kernel_kind_cli_names_round_trip() {
    for kind in [KernelKind::Naive, KernelKind::Packed, KernelKind::Simd] {
        assert_eq!(KernelKind::parse(kind.display_name()).unwrap(), kind);
    }
}
