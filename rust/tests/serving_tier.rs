//! Serving-tier invariants, pinned against an **in-test synchronous
//! reference**:
//!
//! * **Conformance** — the async message-driven [`ServingTier`] decodes
//!   bit-identically to a synchronous scheduler that feeds every live
//!   reply into a [`JobState`] in task order, for flat and nested
//!   plans, across every serving knob (depth, batch window, cache,
//!   tenant layout, fleet size). This holds because job ids are
//!   assigned at submission, faults are a pure function of
//!   `(seed, job_id, item)`, and `collect_all` pins the decode set to
//!   the injected faults rather than thread timing.
//! * **Fairness** — deficit-round-robin refills track the configured
//!   weights exactly under contention (observed deterministically via a
//!   zero-worker fleet).
//! * **Batching** — coalesced dispatch rounds never change output bits.
//! * **Cache** — a mutated operand can never be served a stale encode
//!   (content-hash keying), and cached decodes stay exact.
//! * **Cancellation** — a job cancelled mid-stream never completes; its
//!   in-compute replies land as counted stale drops, not cross-job
//!   leakage.

use std::sync::Arc;
use std::time::{Duration, Instant};

use ft_strassen::coding::nested::NestedTaskSet;
use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::coordinator::job::JobState;
use ft_strassen::coordinator::master::MasterConfig;
use ft_strassen::coordinator::task::DispatchPlan;
use ft_strassen::coordinator::tier::{ServingTier, TenantSpec, TierConfig};
use ft_strassen::coordinator::worker::{Backend, FaultAction, FaultPlan, WorkerReply};
use ft_strassen::linalg::blocked::{encode_operand, split_blocks};
use ft_strassen::linalg::matrix::Matrix;
use ft_strassen::sim::rng::Rng;

fn master_cfg(seed: u64) -> MasterConfig {
    MasterConfig {
        deadline: Duration::from_secs(30),
        fault: FaultPlan {
            p_fail: 0.15,
            p_straggle: 0.1,
            delay: Duration::from_millis(5),
        },
        seed,
        fallback_local: true,
        // Deterministic decode set: wait for every live reply.
        collect_all: true,
    }
}

fn no_fault_cfg(seed: u64) -> MasterConfig {
    MasterConfig {
        deadline: Duration::from_secs(30),
        fault: FaultPlan::NONE,
        seed,
        fallback_local: true,
        collect_all: true,
    }
}

fn job_stream(jobs: usize, n: usize, seed: u64) -> Vec<(Matrix, Matrix)> {
    let mut rng = Rng::seeded(seed);
    (0..jobs)
        .map(|_| (Matrix::random(n, n, &mut rng), Matrix::random(n, n, &mut rng)))
        .collect()
}

/// Compute work item `t` exactly as a native worker would (the encode
/// kernel and matmul are deterministic, so this is bit-for-bit the
/// worker's product).
fn reference_product(
    plan: &DispatchPlan,
    a4: &[Matrix; 4],
    b4: &[Matrix; 4],
    t: usize,
) -> Matrix {
    match plan {
        DispatchPlan::Flat(g) => {
            let s = &g.specs[t];
            encode_operand(&s.int_ca(), a4).matmul(&encode_operand(&s.int_cb(), b4))
        }
        DispatchPlan::Nested(g) => {
            let (gi, j) = (t / g.group_size(), t % g.group_size());
            let lo = encode_operand(&g.outer.specs[gi].int_ca(), a4);
            let ro = encode_operand(&g.outer.specs[gi].int_cb(), b4);
            let li = encode_operand(&g.inner.specs[j].int_ca(), &split_blocks(&lo));
            let ri = encode_operand(&g.inner.specs[j].int_cb(), &split_blocks(&ro));
            li.matmul(&ri)
        }
    }
}

/// The synchronous reference scheduler: one job at a time, replies fed
/// in task order, faults sampled exactly as the tier samples them
/// (pure in `(seed, job_id, item)`, job ids assigned in submission
/// order starting at 1). Under `collect_all` every live reply is in
/// the decode set, so reply *order* cannot matter — which is precisely
/// what makes this a valid reference for the async tier.
fn sync_reference(
    plan: &DispatchPlan,
    master: &MasterConfig,
    jobs: &[(Matrix, Matrix)],
) -> Vec<Matrix> {
    jobs.iter()
        .enumerate()
        .map(|(i, (a, b))| {
            let job_id = (i + 1) as u64;
            let a4 = Arc::new(split_blocks(a));
            let b4 = Arc::new(split_blocks(b));
            let items = plan.num_work_items();
            let faults: Vec<FaultAction> = (0..items)
                .map(|t| master.fault.sample_at(master.seed, job_id, t as u64))
                .collect();
            let failures =
                faults.iter().filter(|f| **f == FaultAction::Fail).count();
            let stragglers = faults
                .iter()
                .filter(|f| matches!(f, FaultAction::Delay(_)))
                .count();
            let now = Instant::now();
            let mut job = JobState::new(
                plan,
                job_id,
                a4.clone(),
                b4.clone(),
                now,
                now,
                now + master.deadline,
                failures,
                stragglers,
                false, // collect_all: defer assembly, no eager revocation
            );
            for (t, fault) in faults.iter().enumerate() {
                if *fault == FaultAction::Fail {
                    continue; // an injected failure never replies
                }
                job.on_reply(WorkerReply {
                    job_id,
                    task_id: t,
                    product: Ok(reference_product(plan, &a4, &b4, t)),
                    compute_time: Duration::ZERO,
                });
            }
            if job.is_decodable() {
                job.assemble(&Backend::Native).unwrap()
            } else {
                job.fallback_product()
            }
        })
        .collect()
}

/// Run the same stream through the tier (tenants round-robin over the
/// submissions) and return outputs in submission order.
fn tier_outputs(
    plan: &DispatchPlan,
    cfg: TierConfig,
    workers: Option<usize>,
    jobs: &[(Matrix, Matrix)],
    tenants: &[&str],
) -> Vec<Matrix> {
    let mut tier = ServingTier::with_plan(plan.clone(), Backend::Native, cfg, workers);
    for (i, (a, b)) in jobs.iter().enumerate() {
        tier.submit(tenants[i % tenants.len()], a.clone(), b.clone()).unwrap();
    }
    let mut done = tier.drive(usize::MAX);
    assert_eq!(done.len(), jobs.len());
    done.sort_by_key(|d| d.job_id);
    let out = done.into_iter().map(|d| d.result.unwrap().0).collect();
    tier.shutdown();
    out
}

fn two_tenants() -> Vec<TenantSpec> {
    vec![TenantSpec::new("heavy", 3, 8), TenantSpec::new("light", 1, 8)]
}

fn assert_bits(want: &[Matrix], got: &[Matrix], what: &str) {
    assert_eq!(want.len(), got.len());
    for (i, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(
            w.as_slice(),
            g.as_slice(),
            "job {} diverged from the synchronous reference ({what})",
            i + 1
        );
    }
}

#[test]
fn flat_tier_matches_sync_reference_across_all_serving_knobs() {
    let plan = DispatchPlan::flat(TaskSet::strassen_winograd(2));
    let jobs = job_stream(6, 16, 42);
    let want = sync_reference(&plan, &master_cfg(42), &jobs);
    // The reference itself must be *correct*, not merely self-consistent.
    for ((a, b), c) in jobs.iter().zip(&want) {
        assert!(c.approx_eq(&a.matmul(b), 1e-3), "rel {}", c.rel_error(&a.matmul(b)));
    }
    for depth in [1, 4] {
        for window in [1, 3] {
            for cache in [0, 8] {
                let cfg = TierConfig {
                    master: master_cfg(42),
                    depth,
                    queue_cap: 64,
                    tenants: two_tenants(),
                    batch_window: window,
                    cache_cap: cache,
                };
                let got = tier_outputs(&plan, cfg, None, &jobs, &["heavy", "light"]);
                assert_bits(
                    &want,
                    &got,
                    &format!("depth {depth} window {window} cache {cache}"),
                );
            }
        }
    }
}

#[test]
fn flat_tier_matches_sync_reference_on_a_tiny_fleet() {
    // Fleet size only changes *where* items run, never what they
    // compute: 96 work items multiplexed onto 3 workers must produce
    // the same bits as the one-node-per-task fleet and the reference.
    let plan = DispatchPlan::flat(TaskSet::strassen_winograd(2));
    let jobs = job_stream(6, 16, 42);
    let want = sync_reference(&plan, &master_cfg(42), &jobs);
    let cfg = TierConfig {
        master: master_cfg(42),
        depth: 4,
        queue_cap: 64,
        tenants: two_tenants(),
        batch_window: 2,
        cache_cap: 4,
    };
    let got = tier_outputs(&plan, cfg, Some(3), &jobs, &["heavy", "light"]);
    assert_bits(&want, &got, "3-worker fleet");
}

#[test]
fn nested_tier_matches_sync_reference() {
    let plan = DispatchPlan::nested(NestedTaskSet::compose(
        TaskSet::strassen_winograd(0),
        TaskSet::strassen_winograd(0),
    ));
    let jobs = job_stream(4, 16, 7);
    let want = sync_reference(&plan, &master_cfg(7), &jobs);
    for (depth, window) in [(1, 1), (4, 3)] {
        let cfg = TierConfig {
            master: master_cfg(7),
            depth,
            queue_cap: 64,
            tenants: two_tenants(),
            batch_window: window,
            cache_cap: 0,
        };
        let got = tier_outputs(&plan, cfg, Some(24), &jobs, &["heavy", "light"]);
        assert_bits(&want, &got, &format!("nested depth {depth} window {window}"));
    }
}

#[test]
fn batch_window_is_bit_invisible() {
    // The explicit pairwise form of the batching clause: the same
    // faulty stream through window 1 and window 5 decodes to the same
    // bits — batching chunks dispatch rounds, it never reorders the
    // fault pattern or the decode set.
    let plan = DispatchPlan::flat(TaskSet::strassen_winograd(2));
    let jobs = job_stream(8, 16, 11);
    let run = |window: usize| {
        let cfg = TierConfig {
            master: master_cfg(11),
            depth: 8,
            queue_cap: 64,
            tenants: vec![TenantSpec::unbounded("default")],
            batch_window: window,
            cache_cap: 0,
        };
        tier_outputs(&plan, cfg, None, &jobs, &["default"])
    };
    let (one, five) = (run(1), run(5));
    assert_bits(&one, &five, "window 1 vs window 5");
}

#[test]
fn drr_refills_track_weights_exactly_under_contention() {
    // Zero workers: nothing completes, so admission state is fully
    // deterministic. Fill all depth-8 slots with one tenant, queue a
    // backlog for both, then free slots one at a time (cancel) — the
    // refills must follow the 3:1 DRR schedule exactly: the starved
    // tenant is served first, then 6 heavy / 2 light over the window.
    let mut tier = ServingTier::with_plan(
        DispatchPlan::flat(TaskSet::strassen_winograd(0)),
        Backend::Native,
        TierConfig {
            master: no_fault_cfg(1),
            depth: 8,
            queue_cap: usize::MAX,
            tenants: vec![
                TenantSpec::new("heavy", 3, usize::MAX),
                TenantSpec::new("light", 1, usize::MAX),
            ],
            batch_window: 1,
            cache_cap: 0,
        },
        Some(0),
    );
    let zeros = || (Matrix::zeros(8, 8), Matrix::zeros(8, 8));
    let mut heavy_ids = Vec::new();
    for _ in 0..16 {
        let (a, b) = zeros();
        heavy_ids.push(tier.submit("heavy", a, b).unwrap());
    }
    for _ in 0..16 {
        let (a, b) = zeros();
        tier.submit("light", a, b).unwrap();
    }
    // Eager admission filled every slot with the first tenant's jobs.
    assert_eq!(tier.tenant_inflight("heavy"), Some(8));
    assert_eq!(tier.tenant_inflight("light"), Some(0));
    for id in &heavy_ids[..8] {
        assert!(tier.cancel(*id), "in-flight job {id} must be cancellable");
    }
    // 8 refills under contention: 6 heavy + 2 light (weights 3:1).
    assert_eq!(tier.tenant_inflight("heavy"), Some(6));
    assert_eq!(tier.tenant_inflight("light"), Some(2));
    assert_eq!(tier.tenant_queued("heavy"), Some(2));
    assert_eq!(tier.tenant_queued("light"), Some(14));
    tier.shutdown();
}

#[test]
fn cache_never_serves_a_stale_encode_for_a_mutated_operand() {
    // Small-integer operands: full-reply decode is bit-exact, so any
    // stale cached encode would show up as a hard inequality.
    let mut tier = ServingTier::new(
        TaskSet::strassen_winograd(2),
        Backend::Native,
        TierConfig {
            master: no_fault_cfg(1),
            depth: 1,
            queue_cap: 64,
            tenants: vec![TenantSpec::unbounded("default")],
            batch_window: 1,
            cache_cap: 4,
        },
    );
    let mut rng = Rng::seeded(5);
    let a = Matrix::from_fn(16, 16, |_, _| (rng.below(7) as f32) - 3.0);
    let b = Matrix::from_fn(16, 16, |_, _| (rng.below(7) as f32) - 3.0);
    // In-place mutation of one element: the content hash must change,
    // so the mutated operand can never alias the cached encodes.
    let mut data: Vec<f32> = a.as_slice().to_vec();
    data[17] += 1.0;
    let a2 = Matrix::from_slice(16, 16, &data);

    tier.submit("default", a.clone(), b.clone()).unwrap(); // miss
    tier.submit("default", a.clone(), b.clone()).unwrap(); // hit
    tier.submit("default", a2.clone(), b.clone()).unwrap(); // miss (mutated)
    let mut done = tier.drive(3);
    assert_eq!(done.len(), 3);
    done.sort_by_key(|d| d.job_id);
    let want = [a.matmul(&b), a.matmul(&b), a2.matmul(&b)];
    for (d, w) in done.iter().zip(&want) {
        let (c, _) = d.result.as_ref().unwrap();
        assert_eq!(c.as_slice(), w.as_slice(), "integer decode must be exact");
    }
    assert_eq!(tier.metrics.counter("cache_hits").get(), 1);
    assert_eq!(tier.metrics.counter("cache_misses").get(), 2);
    tier.shutdown();
}

#[test]
fn cancelled_job_never_completes_and_its_replies_drop_stale() {
    let mut tier = ServingTier::new(
        TaskSet::strassen_winograd(2),
        Backend::Native,
        TierConfig {
            master: no_fault_cfg(1),
            depth: 4,
            queue_cap: 64,
            tenants: vec![TenantSpec::unbounded("default")],
            batch_window: 1,
            cache_cap: 0,
        },
    );
    let (a, b) = {
        let mut rng = Rng::seeded(3);
        (Matrix::random(16, 16, &mut rng), Matrix::random(16, 16, &mut rng))
    };
    // Job 1: every reply rides the delay line. Wait until all 16 items
    // have been *executed* (in the delay line, slots free) so that the
    // cancel below cannot purge anything from the central queue — all
    // 16 replies must then arrive stale.
    let j1 = tier
        .submit_with_faults(
            "default",
            a.clone(),
            b.clone(),
            vec![FaultAction::Delay(Duration::from_millis(400)); 16],
        )
        .unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    while tier.metrics.counter("pool_items_executed").get() < 16 {
        assert!(Instant::now() < deadline, "workers never picked up the items");
        tier.poll(Duration::from_millis(20), usize::MAX);
    }
    assert!(tier.cancel(j1), "in-flight job must be cancellable");
    assert_eq!(tier.outstanding(), 0);

    // Job 2 stays in flight past job 1's reply due-time, keeping the
    // tier polling while the stale replies land.
    let j2 = tier
        .submit_with_faults(
            "default",
            a.clone(),
            b.clone(),
            vec![FaultAction::Delay(Duration::from_millis(800)); 16],
        )
        .unwrap();
    let done = tier.drive(1);
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].job_id, j2, "the cancelled job must never complete");
    assert!(done[0].result.is_ok());
    assert_eq!(
        tier.metrics.counter("replies_stale_dropped").get(),
        16,
        "every cancelled-job reply must be dropped by the job_id guard"
    );
    assert_eq!(tier.metrics.counter("jobs_cancelled").get(), 1);
    tier.shutdown();
}
