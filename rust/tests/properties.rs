//! Randomized property tests (testkit-driven) over the coding and
//! coordinator invariants:
//!
//! * decodability is monotone in the finished set,
//! * span decoding == exhaustive-FC accounting,
//! * peeling never succeeds where span fails,
//! * decode weights always reconstruct the exact bilinear targets,
//! * eq. (10) == exhaustive counting for every c,
//! * the master's routing assigns every task exactly once.

use ft_strassen::algebra::form::Target;
use ft_strassen::algebra::gauss::solve_in_span;
use ft_strassen::coding::decoder::{PeelingDecoder, SpanDecoder};
use ft_strassen::coding::fc::{binomial, fc_table};
use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::coding::theory::replication_fc;
use ft_strassen::coordinator::task::TaskGraph;
use ft_strassen::search::searchlp::SearchOptions;
use ft_strassen::testkit::{check_panics, gen, PropConfig};

fn all_schemes() -> Vec<TaskSet> {
    TaskSet::fig2_schemes()
}

#[test]
fn prop_decodability_is_monotone() {
    // Removing a failure never breaks decodability.
    for ts in [TaskSet::strassen_winograd(0), TaskSet::strassen_winograd(2)] {
        let m = ts.num_tasks();
        check_panics("monotone", PropConfig { cases: 300, base_seed: 0xa }, |rng| {
            let failed = gen::subset_mask(rng, m);
            if ts.decodable_with_failures(failed) {
                return;
            }
            // undecodable stays undecodable when MORE nodes fail
            let extra = gen::subset_mask(rng, m);
            assert!(
                !ts.decodable_with_failures(failed | extra),
                "superset of undecodable {failed:#x} became decodable"
            );
        });
    }
}

#[test]
fn prop_online_decoder_matches_batch_oracle() {
    for ts in all_schemes() {
        let m = ts.num_tasks();
        if m > 16 {
            continue; // mask-based oracle capped at 16 for runtime
        }
        check_panics("online==batch", PropConfig { cases: 200, base_seed: 0xb }, |rng| {
            let failed = gen::subset_mask(rng, m);
            let mut dec = SpanDecoder::new(&ts);
            let mut online = false;
            for i in 0..m {
                if failed & (1 << i) == 0 {
                    online = dec.on_finished(i);
                }
            }
            // empty finished set: on_finished never called
            let batch = ts.decodable_with_failures(failed);
            assert_eq!(
                online || dec.is_decodable(),
                batch,
                "scheme {} mask {failed:#x}",
                ts.name
            );
        });
    }
}

#[test]
fn prop_peeling_subset_of_span() {
    let ts = TaskSet::strassen_winograd(2);
    let peeler = PeelingDecoder::new(&ts, &SearchOptions::default());
    let m = ts.num_tasks();
    check_panics("peel<=span", PropConfig { cases: 500, base_seed: 0xc }, |rng| {
        let failed = gen::subset_mask(rng, m);
        let finished = !failed & ((1u64 << m) - 1);
        if peeler.run(finished).decoded {
            assert!(ts.decodable_with_failures(failed));
        }
    });
}

#[test]
fn prop_decode_weights_reconstruct_targets() {
    let ts = TaskSet::strassen_winograd(2);
    let forms = ts.forms();
    let m = ts.num_tasks();
    check_panics("weights exact", PropConfig { cases: 100, base_seed: 0xd }, |rng| {
        let failed = gen::subset_mask(rng, m);
        if !ts.decodable_with_failures(failed) {
            return;
        }
        let alive: Vec<_> = (0..m).filter(|i| failed & (1 << i) == 0).collect();
        let alive_forms: Vec<_> = alive.iter().map(|&i| forms[i]).collect();
        for t in Target::ALL {
            let w = solve_in_span(&alive_forms, &t.form())
                .expect("decodable implies solvable");
            // Exact symbolic reconstruction.
            let mut acc = [0i64; 16];
            for (wi, f) in w.iter().zip(alive_forms.iter()) {
                // all built-in schemes decode with rational weights; the
                // accumulator works over numerator/denominator lcm
                for j in 0..16 {
                    // wi * coeff must still be rational; use exact check
                    // via f64 would risk; multiply through denominator:
                    acc[j] += (wi.numerator() as i64)
                        * (f.coeffs[j] as i64)
                        * (120 / wi.denominator() as i64); // lcm trick below
                }
            }
            // verify against target scaled by 120 (denominators of the
            // built-in schemes divide 120 — assert that first)
            for wi in &w {
                assert_eq!(
                    120 % wi.denominator(),
                    0,
                    "unexpected denominator {}",
                    wi.denominator()
                );
            }
            for j in 0..16 {
                assert_eq!(
                    acc[j],
                    t.form().coeffs[j] as i64 * 120,
                    "target {t} coeff {j}"
                );
            }
        }
    });
}

#[test]
fn prop_eq10_matches_exhaustive_for_all_c() {
    for c in 1..=3usize {
        let ts = TaskSet::replication(&ft_strassen::algorithms::strassen(), c);
        let table = fc_table(&ts);
        for k in 0..=ts.num_tasks() {
            assert_eq!(table.counts[k], replication_fc(c, k), "c={c} k={k}");
        }
        // sanity: FC(k) <= C(M, k)
        for k in 0..=ts.num_tasks() {
            assert!(table.counts[k] <= binomial(ts.num_tasks() as u64, k as u64) as u64);
        }
    }
}

#[test]
fn prop_task_graph_routes_every_task_once() {
    for ts in all_schemes() {
        let g = TaskGraph::new(ts);
        let mut seen = vec![false; g.num_tasks()];
        for spec in &g.specs {
            assert!(!seen[spec.id], "task {} routed twice", spec.id);
            seen[spec.id] = true;
            // encoding coefficients must be in {-1, 0, 1} for all the
            // paper's schemes (pure sign combinations)
            for c in spec.ca.iter().chain(spec.cb.iter()) {
                assert!(
                    *c == -1.0 || *c == 0.0 || *c == 1.0,
                    "non-sign coefficient {c}"
                );
            }
        }
        assert!(seen.iter().all(|&s| s), "unrouted tasks");
    }
}

#[test]
fn prop_fc_tables_are_sandwiched_by_counts() {
    // 0 <= FC(k) <= C(M,k), FC(0)=0, FC(M)=1 for every scheme.
    for ts in all_schemes() {
        let t = fc_table(&ts);
        let m = ts.num_tasks();
        assert_eq!(t.counts[0], 0, "{}", ts.name);
        assert_eq!(t.counts[m], 1, "{}", ts.name);
        for k in 0..=m {
            assert!(t.counts[k] <= binomial(m as u64, k as u64) as u64);
        }
        // FC(k)/C(M,k) is monotone nondecreasing in k (more failures
        // can only be worse on average).
        let mut last = 0.0;
        for k in 0..=m {
            let frac = t.fatal_fraction(k);
            assert!(frac >= last - 1e-12, "{} k={k}", ts.name);
            last = frac;
        }
    }
}
