//! Integration test for the paper's headline claims (§IV):
//!
//! 1. The proposed S+W scheme with 2 PSMMs uses 16 nodes vs 21 for
//!    3-copy Strassen (-24%).
//! 2. Its reliability is "very close" to 3-copy and strictly better than
//!    the 14-node schemes across the whole p_e range.
//! 3. Theory (eq. 9 + computed FC) and Monte Carlo agree.

use ft_strassen::coding::fc::fc_table;
use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::coding::theory::{failure_probability, replication_failure_probability};
use ft_strassen::sim::montecarlo::MonteCarlo;

#[test]
fn node_counts_16_vs_21() {
    let proposed = TaskSet::strassen_winograd(2);
    let threecopy = TaskSet::replication(&ft_strassen::algorithms::strassen(), 3);
    assert_eq!(proposed.num_tasks(), 16);
    assert_eq!(threecopy.num_tasks(), 21);
    let reduction = 1.0 - proposed.num_tasks() as f64 / threecopy.num_tasks() as f64;
    assert!((reduction - 0.238).abs() < 0.01, "~24% reduction, got {reduction}");
}

#[test]
fn fig2_ordering_holds_across_pe_range() {
    // S x1 >> S x2 > S+W+0 > S+W+1 > S+W+2 > S x3 for moderate p_e
    // (the proposed 14-node scheme beats 14-node replication outright;
    // the sw+0 and x2 curves cross near p_e ≈ 0.28 — measured: at 0.25
    // sw0 still wins, at 0.30 x2 does — so the sweep stops at 0.25).
    let sw0 = fc_table(&TaskSet::strassen_winograd(0));
    let sw1 = fc_table(&TaskSet::strassen_winograd(1));
    let sw2 = fc_table(&TaskSet::strassen_winograd(2));
    for i in 1..=5 {
        let p = i as f64 * 0.05;
        let s1 = replication_failure_probability(1, p);
        let s2 = replication_failure_probability(2, p);
        let s3 = replication_failure_probability(3, p);
        let p0 = failure_probability(&sw0, p);
        let p1 = failure_probability(&sw1, p);
        let p2 = failure_probability(&sw2, p);
        assert!(s1 > s2, "p={p}: x1 {s1} <= x2 {s2}");
        assert!(s2 > p0, "p={p}: x2 {s2} <= sw0 {p0}");
        assert!(p0 > p1, "p={p}: sw0 {p0} <= sw1 {p1}");
        assert!(p1 > p2, "p={p}: sw1 {p1} <= sw2 {p2}");
        assert!(p2 > s3, "p={p}: sw2 {p2} <= x3 {s3}");
    }
}

#[test]
fn proposed_two_psmm_close_to_three_copy() {
    // "performs very close to three-copy Strassen": both tolerate any 2
    // failures; at small p_e the P_f ratio stays within one order of
    // magnitude (the curves nearly overlap in Fig. 2).
    let sw2 = fc_table(&TaskSet::strassen_winograd(2));
    assert_eq!(sw2.first_loss(), 3, "tolerates any 2 failures, like x3");
    for p in [0.01, 0.02, 0.05, 0.1] {
        let a = failure_probability(&sw2, p);
        let b = replication_failure_probability(3, p);
        let ratio = a / b;
        assert!(
            ratio < 10.0,
            "p={p}: P_f(S+W+2)={a:.3e} vs P_f(x3)={b:.3e}, ratio {ratio:.1}"
        );
    }
}

#[test]
fn theory_matches_monte_carlo_for_proposed_scheme() {
    let ts = TaskSet::strassen_winograd(2);
    let fc = fc_table(&ts);
    let oracle = ft_strassen::coding::fc::DecodeOracle::build(&ts);
    for p in [0.05, 0.1, 0.3] {
        let theory = failure_probability(&fc, p);
        let mc = MonteCarlo::new(400_000, 7)
            .failure_probability(p, ts.num_tasks(), |m| oracle.is_decodable(m));
        let tol = 5.0 * mc.std_err + 1e-6;
        assert!(
            (mc.mean - theory).abs() < tol,
            "p={p}: theory {theory:.4e} vs mc {:.4e} (±{:.1e})",
            mc.mean,
            mc.std_err
        );
    }
}

#[test]
fn proposed_beats_two_copy_at_equal_node_count() {
    // 14-node vs 14-node: the diversity gain with ZERO extra nodes
    // (holds up to the ~0.28 crossover; beyond that node failures are so
    // common that pure duplication's FC(2)=7 vs sw0's richer high-k
    // profile flips the comparison).
    let sw0 = fc_table(&TaskSet::strassen_winograd(0));
    for p in [0.05, 0.1, 0.2, 0.25] {
        let a = failure_probability(&sw0, p);
        let b = replication_failure_probability(2, p);
        assert!(a < b, "p={p}: sw+0 {a} not better than x2 {b}");
    }
}
