//! Cross-backend conformance suite — the contract of the [`Scalar`]
//! refactor.
//!
//! For every backend (`f32`, `f64`, `i64`, `Fp31`), every kernel route
//! (naive / packed / SIMD leaves, recursive at several cutoffs), and
//! every recoverable erasure pattern of the paper's task sets (flat and
//! nested), the decoded output must equal the ground-truth product with
//! `==` — no epsilon anywhere.
//!
//! Exactness is unconditional over `i64` and `Fp` (ring arithmetic is
//! exact and decode divisors are units). For the float backends the
//! suite draws small-integer matrices so every intermediate is an
//! integer far below the 2^24 (f32) / 2^53 (f64) mantissa bound and
//! every decode division is by a power of two — making float routes
//! bit-exact too, which is precisely what lets one `assert_eq!` pin all
//! four backends to the same integer matrix.

use ft_strassen::algebra::fp::Fp31;
use ft_strassen::coding::decoder::SpanDecoder;
use ft_strassen::coding::nested::NestedTaskSet;
use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::linalg::blocked::{encode_operand, split_blocks};
use ft_strassen::linalg::kernel::{self, KernelKind};
use ft_strassen::linalg::matrix::Dense;
use ft_strassen::linalg::recursive::{strassen_mm, winograd_mm, RecursiveConfig};
use ft_strassen::linalg::scalar::Scalar;
use ft_strassen::sim::rng::Rng;
use ft_strassen::testkit::gen::int_matrix;

/// Entry bound for the random integer matrices. With 8×8 operands and
/// |entry| ≤ 3, every encoded block entry is ≤ 12, every product entry
/// ≤ 12·12·4 = 576, and every scaled decode combination stays below
/// ~10^5 — integers exactly representable in f32.
const MAX_ABS: i64 = 3;

fn seeds() -> [u64; 4] {
    [0x5ca1ab1e, 2, 3, 0xdec0de]
}

// ---------------------------------------------------------------------
// Kernel routes: every way to multiply must agree exactly.
// ---------------------------------------------------------------------

/// `Dense::matmul` (the backend's `matmul_alloc` hook) vs the naive
/// reference loop, on every backend.
#[test]
fn matmul_hook_equals_naive_reference_on_every_backend() {
    fn check<S: Scalar>() {
        for seed in seeds() {
            let mut rng = Rng::seeded(seed);
            let a: Dense<S> = int_matrix(&mut rng, 24, 16, MAX_ABS);
            let b: Dense<S> = int_matrix(&mut rng, 16, 20, MAX_ABS);
            assert_eq!(a.matmul(&b), a.matmul_naive(&b), "backend {}", S::BACKEND_NAME);
        }
    }
    check::<f32>();
    check::<f64>();
    check::<i64>();
    check::<Fp31>();
}

/// The three explicit f32 leaf kernels agree exactly on integer inputs
/// (SIMD silently falls back to packed off-AVX2 — same contract).
#[test]
fn f32_kernels_agree_exactly_on_integer_inputs() {
    for seed in seeds() {
        let mut rng = Rng::seeded(seed);
        let a: Dense<f32> = int_matrix(&mut rng, 48, 48, MAX_ABS);
        let b: Dense<f32> = int_matrix(&mut rng, 48, 48, MAX_ABS);
        let want = a.matmul_naive(&b);
        for kind in [KernelKind::Naive, KernelKind::Packed, KernelKind::Simd] {
            let mut got = Dense::<f32>::zeros(48, 48);
            kernel::matmul_into(kind, &a, &b, &mut got, 1);
            assert_eq!(got, want, "kernel {kind:?}");
            let mut got_mt = Dense::<f32>::zeros(48, 48);
            kernel::matmul_into(kind, &a, &b, &mut got_mt, 4);
            assert_eq!(got_mt, want, "kernel {kind:?} (4 threads)");
        }
    }
}

/// Recursive Strassen/Winograd at several crossover/depth settings
/// equals the flat product exactly, on every backend; for f32 the leaf
/// kernel is swept too.
#[test]
fn recursive_routes_are_exact_on_every_backend() {
    fn check<S: Scalar>() {
        let mut rng = Rng::seeded(0xabcd);
        let a: Dense<S> = int_matrix(&mut rng, 40, 40, MAX_ABS);
        let b: Dense<S> = int_matrix(&mut rng, 40, 40, MAX_ABS);
        let want = a.matmul_naive(&b);
        for crossover in [4, 16] {
            for max_depth in [2, usize::MAX] {
                let cfg = RecursiveConfig { crossover, max_depth, ..Default::default() };
                assert_eq!(
                    strassen_mm(&a, &b, &cfg),
                    want,
                    "strassen backend={} crossover={crossover} depth={max_depth}",
                    S::BACKEND_NAME
                );
                assert_eq!(
                    winograd_mm(&a, &b, &cfg),
                    want,
                    "winograd backend={} crossover={crossover} depth={max_depth}",
                    S::BACKEND_NAME
                );
            }
        }
    }
    check::<f32>();
    check::<f64>();
    check::<i64>();
    check::<Fp31>();

    // f32 only: the recursive leaf kernel selection must not change bits.
    let mut rng = Rng::seeded(0xabce);
    let a: Dense<f32> = int_matrix(&mut rng, 40, 40, MAX_ABS);
    let b: Dense<f32> = int_matrix(&mut rng, 40, 40, MAX_ABS);
    let want = a.matmul_naive(&b);
    for leaf in [KernelKind::Naive, KernelKind::Packed, KernelKind::Simd] {
        let cfg = RecursiveConfig { crossover: 8, max_depth: 8, leaf };
        assert_eq!(strassen_mm(&a, &b, &cfg), want, "leaf {leaf:?}");
    }
}

// ---------------------------------------------------------------------
// Flat coded schemes: every recoverable erasure pattern decodes exactly.
// ---------------------------------------------------------------------

/// Worker products for a task set: split, encode per task, multiply.
fn products<S: Scalar>(ts: &TaskSet, a: &Dense<S>, b: &Dense<S>) -> Vec<Dense<S>> {
    let a4 = split_blocks(a);
    let b4 = split_blocks(b);
    ts.tasks
        .iter()
        .map(|t| encode_operand(&t.u, &a4).matmul(&encode_operand(&t.v, &b4)))
        .collect()
}

/// Exact decode of one failure pattern; `None` when the span decoder
/// reports the pattern unrecoverable.
fn decode_pattern<S: Scalar>(
    ts: &TaskSet,
    all: &[Dense<S>],
    failed_mask: u64,
    n: usize,
) -> Option<Dense<S>> {
    let mut d = SpanDecoder::new(ts);
    let mut decodable = false;
    for i in 0..ts.num_tasks() {
        if failed_mask & (1 << i) == 0 {
            decodable = d.on_finished(i);
        }
    }
    if !decodable {
        return None;
    }
    let surviving: Vec<Option<Dense<S>>> = all
        .iter()
        .enumerate()
        .map(|(i, p)| (failed_mask & (1 << i) == 0).then(|| p.clone()))
        .collect();
    let mut out = Dense::<S>::zeros(n, n);
    d.combine_exact_into(&surviving, &mut out).unwrap();
    Some(out)
}

/// Every erasure pattern with at most `max_failures` failures that the
/// span decoder accepts must reproduce the ground truth with `==`.
fn check_flat_exhaustive<S: Scalar>(ts: &TaskSet, max_failures: u32) {
    let n = 8;
    let mut rng = Rng::seeded(0xf1a7 ^ ts.num_tasks() as u64);
    let a: Dense<S> = int_matrix(&mut rng, n, n, MAX_ABS);
    let b: Dense<S> = int_matrix(&mut rng, n, n, MAX_ABS);
    let want = a.matmul_naive(&b);
    let all = products(ts, &a, &b);
    let m = ts.num_tasks();
    let mut recovered = 0usize;
    for mask in 0u64..(1 << m) {
        if mask.count_ones() > max_failures {
            continue;
        }
        match decode_pattern(ts, &all, mask, n) {
            Some(got) => {
                assert_eq!(
                    got, want,
                    "backend {} scheme {} failed-mask {mask:#x}",
                    S::BACKEND_NAME, ts.name
                );
                recovered += 1;
            }
            None => assert!(
                !ts.decodable_with_failures(mask),
                "span decoder missed recoverable mask {mask:#x} on {}",
                ts.name
            ),
        }
    }
    assert!(recovered > 0, "no recoverable pattern exercised on {}", ts.name);
}

#[test]
fn flat_decode_is_exact_for_all_small_erasures_i64() {
    check_flat_exhaustive::<i64>(&TaskSet::replication(&ft_strassen::algorithms::strassen(), 1), 2);
    check_flat_exhaustive::<i64>(&TaskSet::strassen_winograd(0), 2);
    check_flat_exhaustive::<i64>(&TaskSet::strassen_winograd(2), 3);
}

#[test]
fn flat_decode_is_exact_for_all_small_erasures_fp31() {
    check_flat_exhaustive::<Fp31>(&TaskSet::strassen_winograd(0), 2);
    check_flat_exhaustive::<Fp31>(&TaskSet::strassen_winograd(2), 3);
}

#[test]
fn flat_decode_is_exact_for_all_small_erasures_floats() {
    check_flat_exhaustive::<f32>(&TaskSet::strassen_winograd(2), 3);
    check_flat_exhaustive::<f64>(&TaskSet::strassen_winograd(2), 3);
}

/// Randomized heavier masks (up to half the fleet dead): whenever the
/// decoder accepts, the output is exact; property-checked over seeds.
#[test]
fn flat_decode_is_exact_on_random_heavy_erasures() {
    fn check<S: Scalar>() {
        let ts = TaskSet::strassen_winograd(2);
        let n = 8;
        let mut rng = Rng::seeded(0xbead);
        let a: Dense<S> = int_matrix(&mut rng, n, n, MAX_ABS);
        let b: Dense<S> = int_matrix(&mut rng, n, n, MAX_ABS);
        let want = a.matmul_naive(&b);
        let all = products(&ts, &a, &b);
        ft_strassen::testkit::check_panics(
            "heavy-erasure exact decode",
            ft_strassen::testkit::PropConfig { cases: 64, ..Default::default() },
            |case_rng| {
                let mask = ft_strassen::testkit::gen::subset_mask(case_rng, ts.num_tasks())
                    & ft_strassen::testkit::gen::subset_mask(case_rng, ts.num_tasks());
                if let Some(got) = decode_pattern(&ts, &all, mask, n) {
                    assert_eq!(got, want, "backend {} mask {mask:#x}", S::BACKEND_NAME);
                }
            },
        );
    }
    check::<i64>();
    check::<Fp31>();
}

// ---------------------------------------------------------------------
// Nested two-level schemes: two-stage decode is exact end to end.
// ---------------------------------------------------------------------

/// Leaf products of a nested scheme: encode the outer operands per
/// group, then the inner operands per leaf (the coordinator's layout:
/// leaf (g, j) computes the inner product j of outer product g).
fn nested_leaf_products<S: Scalar>(
    set: &NestedTaskSet,
    a: &Dense<S>,
    b: &Dense<S>,
) -> Vec<Vec<Dense<S>>> {
    let a4 = split_blocks(a);
    let b4 = split_blocks(b);
    (0..set.num_groups())
        .map(|g| {
            let lo = encode_operand(&set.outer.tasks[g].u, &a4);
            let ro = encode_operand(&set.outer.tasks[g].v, &b4);
            let li = split_blocks(&lo);
            let ri = split_blocks(&ro);
            (0..set.group_size())
                .map(|j| {
                    encode_operand(&set.inner.tasks[j].u, &li)
                        .matmul(&encode_operand(&set.inner.tasks[j].v, &ri))
                })
                .collect()
        })
        .collect()
}

/// Two-stage exact decode: inner combine per group (skipping failed
/// leaves), then outer combine over the recovered group products.
fn nested_decode<S: Scalar>(
    set: &NestedTaskSet,
    leaves: &[Vec<Dense<S>>],
    group_failed: &[u64],
    n: usize,
) -> Option<Dense<S>> {
    let mut outer_products: Vec<Option<Dense<S>>> = vec![None; set.num_groups()];
    for g in 0..set.num_groups() {
        let mut d = SpanDecoder::new(&set.inner);
        let mut ok = false;
        for j in 0..set.group_size() {
            if group_failed[g] & (1 << j) == 0 {
                ok = d.on_finished(j);
            }
        }
        if !ok {
            continue; // this outer product is lost
        }
        let surviving: Vec<Option<Dense<S>>> = leaves[g]
            .iter()
            .enumerate()
            .map(|(j, p)| (group_failed[g] & (1 << j) == 0).then(|| p.clone()))
            .collect();
        let mut pg = Dense::<S>::zeros(n / 2, n / 2);
        d.combine_exact_into(&surviving, &mut pg).unwrap();
        outer_products[g] = Some(pg);
    }
    let mut d = SpanDecoder::new(&set.outer);
    let mut ok = false;
    for (g, p) in outer_products.iter().enumerate() {
        if p.is_some() {
            ok = d.on_finished(g);
        }
    }
    if !ok {
        return None;
    }
    let mut out = Dense::<S>::zeros(n, n);
    d.combine_exact_into(&outer_products, &mut out).unwrap();
    Some(out)
}

#[test]
fn nested_two_stage_decode_is_exact_on_every_backend() {
    fn check<S: Scalar>() {
        let set = NestedTaskSet::compose(
            TaskSet::strassen_winograd(0),
            TaskSet::strassen_winograd(2),
        );
        let n = 8;
        let mut rng = Rng::seeded(0x2f2f);
        let a: Dense<S> = int_matrix(&mut rng, n, n, MAX_ABS);
        let b: Dense<S> = int_matrix(&mut rng, n, n, MAX_ABS);
        let want = a.matmul_naive(&b);
        let leaves = nested_leaf_products(&set, &a, &b);

        // No failures at all.
        let clean = vec![0u64; set.num_groups()];
        assert_eq!(nested_decode(&set, &leaves, &clean, n).unwrap(), want);

        // Group 3 entirely dead (outer tolerates one lost group) plus
        // scattered recoverable leaf failures elsewhere.
        let mut failed = vec![0u64; set.num_groups()];
        failed[3] = (1 << set.group_size()) - 1;
        failed[0] = (1 << 2) | (1 << 11); // S3+W5, covered via PSMM-1
        failed[7] = 1 << 5;
        assert!(set.decodable_with_failures(&failed));
        assert_eq!(
            nested_decode(&set, &leaves, &failed, n).unwrap(),
            want,
            "backend {}",
            S::BACKEND_NAME
        );

        // Two dead groups defeat the sw(0) outer code: decode must
        // refuse rather than fabricate output.
        failed[5] = (1 << set.group_size()) - 1;
        assert!(!set.decodable_with_failures(&failed));
        assert!(nested_decode(&set, &leaves, &failed, n).is_none());
    }
    check::<i64>();
    check::<Fp31>();
    check::<f64>();
    check::<f32>();
}

/// Cross-backend agreement: the i64 decode (exact by construction) is
/// the reference; every other backend's decode of the same integer
/// matrices must map to the same integers entry-for-entry.
#[test]
fn all_backends_decode_to_the_same_integers() {
    let ts = TaskSet::strassen_winograd(2);
    let n = 8;
    let draw = |seed: u64| {
        let mut rng = Rng::seeded(seed);
        (
            int_matrix::<i64>(&mut rng, n, n, MAX_ABS),
            int_matrix::<i64>(&mut rng, n, n, MAX_ABS),
        )
    };
    let (ai, bi) = draw(0x77);
    let reference = {
        let all = products(&ts, &ai, &bi);
        decode_pattern(&ts, &all, (1 << 2) | (1 << 11), n).unwrap()
    };
    fn decode_as<S: Scalar>(ts: &TaskSet, n: usize, seed: u64) -> Dense<S> {
        let mut rng = Rng::seeded(seed);
        let a: Dense<S> = int_matrix(&mut rng, n, n, MAX_ABS);
        let b: Dense<S> = int_matrix(&mut rng, n, n, MAX_ABS);
        let all = products(ts, &a, &b);
        decode_pattern(ts, &all, (1 << 2) | (1 << 11), n).unwrap()
    }
    let as_f32 = decode_as::<f32>(&ts, n, 0x77);
    let as_f64 = decode_as::<f64>(&ts, n, 0x77);
    let as_fp = decode_as::<Fp31>(&ts, n, 0x77);
    for i in 0..n {
        for j in 0..n {
            let x = reference[(i, j)];
            assert_eq!(as_f32[(i, j)], x as f32);
            assert_eq!(as_f64[(i, j)], x as f64);
            assert_eq!(as_fp[(i, j)], Fp31::from_i64(x));
        }
    }
}
