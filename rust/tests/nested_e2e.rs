//! Nested two-level scheme invariants, end to end:
//!
//! * a 16×16-composed job (256 leaves) over the multiplexed scheduler
//!   survives **any** single-group wipeout plus scattered sub-threshold
//!   leaf failures, and the decoded C equals the single-node recursive
//!   ground truth (`linalg::recursive`) exactly — integer operands make
//!   every intermediate exactly representable, so decode equality is
//!   bit-exact, not approximate;
//! * random recoverable failure patterns (per-leaf Bernoulli, accepted
//!   by the [`NestedOracle`]) also decode bit-identically to the ground
//!   truth;
//! * nested serving is bit-reproducible across scheduler depths under
//!   `collect_all`, like the flat schemes in `tests/multiplex.rs`;
//! * `first_loss` of a composition is the product of the per-level
//!   values — in particular at least the per-level minimum.

use std::time::Duration;

use ft_strassen::coding::fc::fc_table;
use ft_strassen::coding::nested::{NestedOracle, NestedTaskSet};
use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::coordinator::master::MasterConfig;
use ft_strassen::coordinator::scheduler::{Scheduler, SchedulerConfig};
use ft_strassen::coordinator::task::DispatchPlan;
use ft_strassen::coordinator::worker::{Backend, FaultAction, FaultPlan};
use ft_strassen::linalg::matrix::Matrix;
use ft_strassen::linalg::recursive::{strassen_mm, RecursiveConfig};
use ft_strassen::sim::rng::Rng;

fn int_matrix(n: usize, rng: &mut Rng) -> Matrix {
    // Small integers: all products, encodes and (dyadic-weight) decodes
    // are exact in f32, so equality assertions are bit-exact.
    Matrix::from_fn(n, n, |_, _| (rng.below(7) as f32) - 3.0)
}

/// Single-node recursive ground truth: two levels of 2×2 splitting,
/// exactly mirroring the nested dispatch structure.
fn ground_truth(a: &Matrix, b: &Matrix) -> Matrix {
    strassen_mm(a, b, &RecursiveConfig { crossover: 4, max_depth: 2, ..Default::default() })
}

fn sw2_squared_plan() -> DispatchPlan {
    DispatchPlan::nested(NestedTaskSet::compose(
        TaskSet::strassen_winograd(2),
        TaskSet::strassen_winograd(2),
    ))
}

fn cfg(depth: usize, fault: FaultPlan, collect_all: bool) -> SchedulerConfig {
    SchedulerConfig {
        master: MasterConfig {
            deadline: Duration::from_secs(30),
            fault,
            seed: 1,
            // No silent degradation: a decode failure fails the test.
            fallback_local: false,
            collect_all,
        },
        depth,
    }
}

#[test]
fn nested_survives_any_single_group_wipeout_plus_scatter() {
    let m2 = 16;
    let leaves = 256;
    let mut s = Scheduler::with_plan(
        sw2_squared_plan(),
        Backend::Native,
        cfg(4, FaultPlan::NONE, false),
        Some(16),
    );
    let mut rng = Rng::seeded(42);
    let mut want = Vec::new();
    for g in 0..16usize {
        let a = int_matrix(32, &mut rng);
        let b = int_matrix(32, &mut rng);
        want.push(ground_truth(&a, &b));
        // Wipe out group g entirely (16 dead leaves = one whole outer
        // product), plus two scattered failures in each of two other
        // groups (below the inner first_loss of 3), plus stragglers.
        let mut faults = vec![FaultAction::None; leaves];
        for j in 0..m2 {
            faults[g * m2 + j] = FaultAction::Fail;
        }
        for other in [(g + 1) % 16, (g + 5) % 16] {
            faults[other * m2 + 1] = FaultAction::Fail;
            faults[other * m2 + 7] = FaultAction::Fail;
        }
        faults[(g + 3) % 16 * m2 + 2] = FaultAction::Delay(Duration::from_millis(5));
        s.submit_with_faults(a, b, faults).unwrap();
    }
    let mut done = s.drive(16);
    assert_eq!(done.len(), 16);
    done.sort_by_key(|f| f.job_id);
    for (i, f) in done.iter().enumerate() {
        let (c, report) = f.result.as_ref().unwrap_or_else(|e| {
            panic!("job {} (wiped group {}) failed to decode: {e}", f.job_id, i)
        });
        assert!(!report.fell_back);
        assert_eq!(report.injected_failures, 20);
        assert_eq!(
            c.as_slice(),
            want[i].as_slice(),
            "wiped group {i}: decode differs from recursive ground truth"
        );
    }
    s.shutdown();
}

#[test]
fn nested_decodes_random_recoverable_patterns_bit_exactly() {
    let set = NestedTaskSet::compose(
        TaskSet::strassen_winograd(2),
        TaskSet::strassen_winograd(2),
    );
    let oracle = NestedOracle::build(&set);
    let (m1, m2) = (set.num_groups(), set.group_size());
    let mut s = Scheduler::with_plan(
        DispatchPlan::nested(set),
        Backend::Native,
        cfg(2, FaultPlan::NONE, false),
        Some(16),
    );
    let mut rng = Rng::seeded(7);
    let mut want = Vec::new();
    let mut submitted = 0;
    while submitted < 6 {
        // Random per-leaf failure pattern; keep only recoverable ones
        // (the property under test is decode exactness, not coverage).
        let mut masks = vec![0u64; m1];
        let mut faults = vec![FaultAction::None; m1 * m2];
        for g in 0..m1 {
            for j in 0..m2 {
                if rng.bernoulli(0.06) {
                    masks[g] |= 1 << j;
                    faults[g * m2 + j] = FaultAction::Fail;
                }
            }
        }
        if !oracle.is_decodable(&masks) {
            continue;
        }
        let a = int_matrix(16, &mut rng);
        let b = int_matrix(16, &mut rng);
        want.push(ground_truth(&a, &b));
        s.submit_with_faults(a, b, faults).unwrap();
        submitted += 1;
    }
    let mut done = s.drive(6);
    assert_eq!(done.len(), 6);
    done.sort_by_key(|f| f.job_id);
    for (f, w) in done.iter().zip(&want) {
        let (c, report) = f.result.as_ref().unwrap();
        assert!(!report.fell_back);
        assert_eq!(c.as_slice(), w.as_slice(), "job {}", f.job_id);
    }
    s.shutdown();
}

#[test]
fn nested_collect_all_is_bit_reproducible_across_depths() {
    let jobs = 4;
    let n = 16;
    let fault = FaultPlan { p_fail: 0.1, p_straggle: 0.0, delay: Duration::ZERO };
    let run = |depth: usize| -> Vec<Matrix> {
        let plan = DispatchPlan::nested(NestedTaskSet::compose(
            TaskSet::strassen_winograd(0),
            TaskSet::strassen_winograd(0),
        ));
        let mut cfg = cfg(depth, fault, true);
        cfg.master.fallback_local = true;
        let mut s = Scheduler::with_plan(plan, Backend::Native, cfg, Some(28));
        let mut rng = Rng::seeded(9);
        for _ in 0..jobs {
            let a = Matrix::random(n, n, &mut rng);
            let b = Matrix::random(n, n, &mut rng);
            s.submit(a, b).unwrap();
        }
        let mut done = s.drive(jobs);
        assert_eq!(done.len(), jobs);
        done.sort_by_key(|f| f.job_id);
        let out = done
            .into_iter()
            .map(|f| f.result.unwrap().0)
            .collect();
        s.shutdown();
        out
    };
    let d1 = run(1);
    let d3 = run(3);
    for (i, (x, y)) in d1.iter().zip(&d3).enumerate() {
        assert_eq!(
            x.as_slice(),
            y.as_slice(),
            "job {} diverged between depth 1 and depth 3",
            i + 1
        );
    }
}

#[test]
fn nested_first_loss_at_least_per_level_minimum() {
    use ft_strassen::algorithms::strassen;
    for (outer, inner) in [
        (TaskSet::strassen_winograd(2), TaskSet::strassen_winograd(2)),
        (TaskSet::strassen_winograd(2), TaskSet::replication(&strassen(), 2)),
        (TaskSet::replication(&strassen(), 3), TaskSet::strassen_winograd(0)),
        (TaskSet::replication(&strassen(), 1), TaskSet::strassen_winograd(2)),
    ] {
        let d_outer = fc_table(&outer).first_loss();
        let d_inner = fc_table(&inner).first_loss();
        let nested = NestedTaskSet::compose(outer, inner);
        let got = nested.first_loss();
        assert_eq!(got, d_outer * d_inner, "{}", nested.name);
        assert!(got >= d_outer.min(d_inner), "{}", nested.name);
        assert!(got >= d_outer.max(d_inner), "{}", nested.name);
    }
}
