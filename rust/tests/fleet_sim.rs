//! Fleet-simulator cross-validation and determinism suite.
//!
//! * The discrete-event simulator (`sim::des`), run with deterministic
//!   latency and Bernoulli faults, must reproduce the static
//!   `sim::MonteCarlo` estimate and the `coding::theory` eq. (9) curve
//!   on the flat schemes — the DES adds dynamics (queueing, dispatch,
//!   backups), not a different failure law.
//! * Identical seed + config must reproduce the event trace byte for
//!   byte, and bookkeeping knobs (heap capacity) or fleet scaling must
//!   never change decode outcomes when faults are pure (`p_rack = 0`).
//! * The acceptance campaign: 10,000 workers, the nested sw+2psmm²
//!   plan (256 leaves/job), p_e swept over the resolvable upper range —
//!   measured P_f tracks `nested_failure_probability` within 4σ.

use std::time::Duration;

use ft_strassen::coding::fc::{fc_table, DecodeOracle};
use ft_strassen::coding::nested::NestedTaskSet;
use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::coding::theory::{failure_probability, nested_failure_probability};
use ft_strassen::coordinator::worker::FaultPlan;
use ft_strassen::sim::des::{
    policy_by_name, ArrivalProcess, Campaign, FleetSpec, LinkModel, SimPlan,
};
use ft_strassen::sim::latency::LatencyModel;
use ft_strassen::sim::montecarlo::MonteCarlo;

/// A clean campaign: deterministic service times, free links, no rack
/// outages — the closest DES analogue of the static Monte-Carlo model.
fn clean_campaign(jobs: usize, workers: usize, p_e: f64, seed: u64) -> Campaign {
    Campaign {
        fleet: FleetSpec {
            workers,
            rack_size: 32,
            p_rack: 0.0,
            speed: LatencyModel::Deterministic { t: 1.0 },
            leaf_latency: LatencyModel::Deterministic { t: 0.01 },
            link: LinkModel::FREE,
        },
        arrivals: ArrivalProcess::Uniform { count: jobs, interarrival: 0.05 },
        fault: FaultPlan { p_fail: p_e, p_straggle: 0.0, delay: Duration::ZERO },
        block_bytes: 0,
        seed,
        max_attempts: 4,
        heap_capacity: 0,
        record_trace: false,
    }
}

#[test]
fn des_reproduces_montecarlo_and_theory_on_flat_schemes() {
    let jobs = 400;
    let slack = 3.0 / jobs as f64; // rule of three: tiny P_f is unresolvable
    for (psmms, p_e) in [(0usize, 0.2), (0, 0.35), (2, 0.2), (2, 0.35)] {
        let ts = TaskSet::strassen_winograd(psmms);
        let m = ts.num_tasks();
        let fc = fc_table(&ts);
        let oracle = DecodeOracle::build(&ts);
        let theory = failure_probability(&fc, p_e);
        let mc = MonteCarlo::new(50_000, 7)
            .failure_probability(p_e, m, |mask| oracle.is_decodable(mask));

        let plan = SimPlan::Flat(ts);
        let mut policy = policy_by_name("random").unwrap();
        let des = clean_campaign(jobs, 64, p_e, 11).run(&plan, policy.as_mut()).summary;

        assert_eq!(des.decoded + des.failed, jobs);
        assert!(
            des.measured_pf.agrees_with(theory, 4.0, slack),
            "sw+{psmms}psmm p_e={p_e}: des {} ± {} vs theory {theory}",
            des.measured_pf.mean,
            des.measured_pf.std_err
        );
        let gap = (des.measured_pf.mean - mc.mean).abs();
        let tol = 4.0 * (des.measured_pf.std_err + mc.std_err) + slack;
        assert!(
            gap <= tol,
            "sw+{psmms}psmm p_e={p_e}: des {} vs mc {} (gap {gap} > tol {tol})",
            des.measured_pf.mean,
            mc.mean
        );
    }
}

#[test]
fn identical_seed_and_config_reproduce_the_run_byte_for_byte() {
    let nested = NestedTaskSet::compose(
        TaskSet::strassen_winograd(0),
        TaskSet::strassen_winograd(0),
    );
    let plan = SimPlan::Nested(nested);
    let mut campaign = clean_campaign(12, 96, 0.25, 99);
    campaign.record_trace = true;
    campaign.fault.p_straggle = 0.2;
    campaign.fault.delay = Duration::from_millis(30);

    let mut a_pol = policy_by_name("speculative").unwrap();
    let mut b_pol = policy_by_name("speculative").unwrap();
    let a = campaign.run(&plan, a_pol.as_mut());
    let b = campaign.run(&plan, b_pol.as_mut());

    assert_eq!(a.summary, b.summary);
    assert!(!a.trace.is_empty());
    assert_eq!(a.trace, b.trace, "event traces diverged under identical config");
}

#[test]
fn heap_capacity_is_pure_bookkeeping_and_fleet_size_cannot_change_outcomes() {
    let plan = SimPlan::Flat(TaskSet::strassen_winograd(2));
    let base = clean_campaign(60, 64, 0.3, 5);

    // Pre-sizing the calendar must not reorder anything.
    let mut sized = base.clone();
    sized.heap_capacity = 4096;
    let mut p1 = policy_by_name("fastest").unwrap();
    let mut p2 = policy_by_name("fastest").unwrap();
    let a = base.run(&plan, p1.as_mut()).summary;
    let b = sized.run(&plan, p2.as_mut()).summary;
    assert_eq!(a, b, "heap capacity changed the simulation");

    // Fault purity: the dead-leaf set depends only on (seed, job, leaf),
    // so growing the fleet reshuffles timing but not decode outcomes.
    for (workers, policy) in [(64, "random"), (500, "random"), (500, "locality")] {
        let mut big = base.clone();
        big.fleet.workers = workers;
        let mut pol = policy_by_name(policy).unwrap();
        let s = big.run(&plan, pol.as_mut()).summary;
        assert_eq!(
            (s.outcome_digest, s.failed),
            (a.outcome_digest, a.failed),
            "outcomes changed at workers={workers} policy={policy}"
        );
    }
}

#[test]
fn ten_thousand_worker_nested_campaign_tracks_fig2_theory() {
    let nested = NestedTaskSet::compose(
        TaskSet::strassen_winograd(2),
        TaskSet::strassen_winograd(2),
    );
    assert_eq!(nested.num_leaves(), 256);
    let fc_o = fc_table(&nested.outer);
    let fc_i = fc_table(&nested.inner);
    let plan = SimPlan::Nested(nested);

    let jobs = 300;
    let slack = 3.0 / jobs as f64;
    let mut policy = policy_by_name("random").unwrap();
    // The upper end of the Fig.-2 range, where a 300-job campaign can
    // actually resolve the nested P_f (it is astronomically small at
    // low p_e — those points are covered by the rule-of-three slack).
    for p_e in [0.3, 0.4, 0.5] {
        let theory = nested_failure_probability(&fc_o, &fc_i, p_e);
        let mut campaign = clean_campaign(jobs, 10_000, p_e, 17);
        campaign.arrivals = ArrivalProcess::Poisson { count: jobs, rate: 300.0 };
        campaign.heap_capacity = jobs * 256 / 4;
        let s = campaign.run(&plan, policy.as_mut()).summary;
        assert_eq!(s.decoded + s.failed, jobs);
        assert!(s.makespan_s > 0.0);
        assert!(
            s.measured_pf.agrees_with(theory, 4.0, slack),
            "p_e={p_e}: des {} ± {} vs nested theory {theory}",
            s.measured_pf.mean,
            s.measured_pf.std_err
        );
    }
}

#[test]
fn scheduling_policies_differ_where_they_should() {
    let plan = SimPlan::Flat(TaskSet::strassen_winograd(2));

    // Bimodal worker speeds: fastest-first must not lose to random on
    // mean completion (generous 10% cushion — it usually wins big).
    let mut bimodal = clean_campaign(40, 256, 0.0, 23);
    bimodal.fleet.speed = LatencyModel::Bimodal { base: 1.0, p_slow: 0.3, factor: 8.0 };
    let mut rand_pol = policy_by_name("random").unwrap();
    let mut fast_pol = policy_by_name("fastest").unwrap();
    let random = bimodal.run(&plan, rand_pol.as_mut()).summary;
    let fastest = bimodal.run(&plan, fast_pol.as_mut()).summary;
    assert_eq!(random.outcome_digest, fastest.outcome_digest);
    assert!(
        fastest.mean_completion_s <= random.mean_completion_s * 1.10,
        "fastest {} vs random {}",
        fastest.mean_completion_s,
        random.mean_completion_s
    );

    // Metered links: locality-aware reuses warm racks, so it must ship
    // strictly fewer bytes than random placement across a wide fleet.
    let mut metered = clean_campaign(6, 512, 0.0, 29);
    metered.block_bytes = 32 * 32 * 8;
    metered.fleet.link = LinkModel { latency_s: 0.001, bytes_per_s: 1e9 };
    let mut rand_pol = policy_by_name("random").unwrap();
    let mut loc_pol = policy_by_name("locality").unwrap();
    let spread = metered.run(&plan, rand_pol.as_mut()).summary;
    let packed = metered.run(&plan, loc_pol.as_mut()).summary;
    assert!(spread.network_bytes > 0);
    assert!(
        packed.network_bytes < spread.network_bytes,
        "locality {} bytes vs random {} bytes",
        packed.network_bytes,
        spread.network_bytes
    );
}
