//! Steady-state regression for the packed-leaf recursive multiply: at
//! warm depth the recursion arena performs **zero matrix clones and
//! zero fresh allocations**, and every leaf routes through the packed
//! kernel exactly once. One test function on purpose —
//! `Matrix::clone_count()`, `Matrix::alloc_count()` and the kernel call
//! counters are process globals, and a single-test binary keeps the
//! measurement window free of concurrent tests (same reasoning as
//! `tests/decode_alloc.rs`).

use ft_strassen::algorithms::strassen;
use ft_strassen::linalg::kernel::{self, KernelKind};
use ft_strassen::linalg::matrix::Matrix;
use ft_strassen::linalg::recursive::{scheme_mm_into, RecursiveConfig};
use ft_strassen::sim::rng::Rng;

#[test]
fn warm_recursion_is_allocation_free_and_routes_leaves_through_packed() {
    let mut rng = Rng::seeded(41);
    let n = 128;
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let scheme = strassen();
    let cfg = RecursiveConfig {
        crossover: 32,
        max_depth: usize::MAX,
        leaf: KernelKind::Packed,
    };
    let saved_threads = kernel::threads();
    kernel::set_threads(1);

    // Warm call: sizes the thread-local recursion arena and the output
    // buffer; correctness against the oracle is pinned here, outside
    // the counted window (`approx_eq`/`rel_error` clone internally).
    let mut out = Matrix::zeros(0, 0);
    scheme_mm_into(&scheme, &a, &b, &mut out, &cfg);
    let want = a.matmul_naive(&b);
    assert!(out.approx_eq(&want, 1e-4), "warm call must match the naive oracle");

    // Counted window: a second, warm multiply. 128 → 64 → 32 hits the
    // crossover after two split levels, so exactly 7² = 49 leaf
    // multiplies route through the packed kernel — and the warm arena
    // plus warm output buffer mean no clones and no fresh allocations.
    let clones = Matrix::clone_count();
    let allocs = Matrix::alloc_count();
    let packed = kernel::packed_call_count();
    scheme_mm_into(&scheme, &a, &b, &mut out, &cfg);
    assert_eq!(kernel::packed_call_count() - packed, 49, "leaf routing: 7^2 packed leaves");
    assert_eq!(Matrix::clone_count() - clones, 0, "steady-depth multiply must not clone");
    assert_eq!(Matrix::alloc_count() - allocs, 0, "warm arena must not allocate");
    assert!(out.approx_eq(&want, 1e-4), "warm result must match the naive oracle");

    // Thread-count invariance: the arena is thread-local and the packed
    // leaf accumulates every element in a fixed ascending-k order, so
    // the recursion is bit-identical across kernel thread counts.
    let serial = out.as_slice().to_vec();
    for t in [2, 5, 16] {
        kernel::set_threads(t);
        scheme_mm_into(&scheme, &a, &b, &mut out, &cfg);
        assert_eq!(out.as_slice(), &serial[..], "threads={t}");
    }
    kernel::set_threads(saved_threads);
}
