//! Bench: the compute hot path across backends — native blocked matmul
//! vs the AOT Pallas artifacts through PJRT (worker task, decode
//! combine, plain matmul, one-level Strassen) — plus the recursive
//! Strassen complexity curve that anchors the O(n^2.81) claim.
//!
//! PJRT benches self-skip when `artifacts/` is missing.

use std::path::Path;

use ft_strassen::bench::harness::BenchRunner;
use ft_strassen::linalg::blocked::split_blocks;
use ft_strassen::linalg::matrix::Matrix;
use ft_strassen::linalg::recursive::{multiplication_count, strassen_mm, RecursiveConfig};
use ft_strassen::runtime::client::Runtime;
use ft_strassen::sim::rng::Rng;

fn main() {
    let mut runner = BenchRunner::from_env();
    let mut rng = Rng::seeded(1);

    // --- native path ------------------------------------------------------
    for n in [64usize, 128, 256] {
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        runner.bench_value(&format!("native/matmul_n{n}"), || a.matmul(&b));
    }
    let a = Matrix::random(256, 256, &mut rng);
    let b = Matrix::random(256, 256, &mut rng);
    runner.bench_value("native/strassen_rec_n256_cut64", || {
        strassen_mm(&a, &b, &RecursiveConfig { cutoff: 64, max_depth: 8 })
    });
    let a4 = split_blocks(&a);
    let b4 = split_blocks(&b);
    runner.bench_value("native/worker_product_bs128", || {
        let left = &a4[0] + &a4[3];
        let right = &b4[0] + &b4[3];
        left.matmul(&right)
    });

    // complexity model table
    println!("\nmultiplication counts (cutoff 32):");
    for n in [64u32, 128, 256, 512, 1024] {
        let s = multiplication_count(7, n as usize, 32);
        let d = multiplication_count(8, n as usize, 32);
        println!(
            "  n={n:5}: strassen {s:>14}  naive {d:>14}  ratio {:.3}",
            s as f64 / d as f64
        );
    }

    // --- PJRT path ----------------------------------------------------------
    let dir = Path::new("artifacts");
    match Runtime::new(dir) {
        Err(e) => println!("\npjrt benches skipped: {e}"),
        Ok(mut rt) => {
            println!("\npjrt: {}", rt.platform());
            for bs in rt.manifest().worker_block_sizes() {
                let blk: [Matrix; 4] =
                    std::array::from_fn(|_| Matrix::random(bs, bs, &mut rng));
                let blk2: [Matrix; 4] =
                    std::array::from_fn(|_| Matrix::random(bs, bs, &mut rng));
                rt.warmup(bs).unwrap();
                runner.bench_value(&format!("pjrt/worker_task_bs{bs}"), || {
                    rt.worker_task(&[1.0, 0.0, 0.0, 1.0], &blk, &[1.0, 0.0, 0.0, 1.0], &blk2)
                        .unwrap()
                });
                let products: Vec<Matrix> =
                    (0..16).map(|_| Matrix::random(bs, bs, &mut rng)).collect();
                let weights: Vec<f32> =
                    (0..16).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
                runner.bench_value(&format!("pjrt/decode_combine_bs{bs}"), || {
                    let refs: Vec<Option<&Matrix>> = products.iter().map(Some).collect();
                    rt.decode_combine(&weights, &refs, bs).unwrap()
                });
                runner.bench_value(&format!("pjrt/strassen_once_bs{bs}"), || {
                    rt.strassen_once(&blk, &blk2).unwrap()
                });
                let n = 2 * bs;
                let a = Matrix::random(n, n, &mut rng);
                let b = Matrix::random(n, n, &mut rng);
                runner.bench_value(&format!("pjrt/matmul_n{n}"), || {
                    rt.matmul(&a, &b).unwrap()
                });
            }
        }
    }

    let out = Path::new("target/bench_results");
    std::fs::create_dir_all(out).unwrap();
    runner.write_csv(&out.join("kernel_timings.csv")).unwrap();
}
