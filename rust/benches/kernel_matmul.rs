//! Bench: the compute hot path across kernels and backends —
//!
//! * **naive vs packed** native matmul (serial and multi-threaded) at
//!   128/256/512, with a bitwise cross-check and the speedup headline
//!   appended to `BENCH_kernel.json` at the repo root;
//! * **alloc-count comparison** of the worker encode path (fresh
//!   allocation per task vs the reusable scratch buffer);
//! * **recursive-vs-flat crossover sweep**: recursive Strassen (arena,
//!   SIMD leaves when the CPU has them) against one flat kernel call
//!   over sizes × crossovers, appended to `BENCH_recursive.json`;
//! * the recursive Strassen complexity curve anchoring O(n^2.81);
//! * the AOT Pallas artifacts through PJRT (worker task, decode
//!   combine, plain matmul, one-level Strassen) — these self-skip when
//!   `artifacts/` is missing.

use std::path::Path;
use std::time::{SystemTime, UNIX_EPOCH};

use ft_strassen::bench::harness::BenchRunner;
use ft_strassen::bench::{schema, trajectory};
use ft_strassen::linalg::blocked::{encode_operand, encode_operand_into, split_blocks};
use ft_strassen::linalg::kernel::{self, KernelKind};
use ft_strassen::linalg::matrix::Matrix;
use ft_strassen::linalg::recursive::{
    multiplication_count, scheme_mm_into, strassen_mm, RecursiveConfig,
};
use ft_strassen::runtime::client::Runtime;
use ft_strassen::sim::rng::Rng;

fn main() {
    let quick = std::env::var("FT_BENCH_QUICK").as_deref() == Ok("1");
    let mut runner = BenchRunner::from_env();
    let mut rng = Rng::seeded(1);
    let mt = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(8);

    // --- naive vs packed ---------------------------------------------------
    println!("kernel comparison (packed-mt uses {mt} threads):");
    let mut rows: Vec<schema::KernelSizeRow> = Vec::new();
    for n in [128usize, 256, 512] {
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        // Cross-check first: the packed kernel must be bit-identical to
        // the naive oracle at every size we report.
        let want = a.matmul_naive(&b);
        assert_eq!(
            kernel::matmul_packed(&a, &b, mt).as_slice(),
            want.as_slice(),
            "packed kernel diverged from naive at n={n}"
        );
        let naive_ns = runner
            .bench_value(&format!("native/naive_n{n}"), || a.matmul_naive(&b))
            .stats
            .mean
            .as_nanos();
        let packed_ns = runner
            .bench_value(&format!("native/packed_n{n}"), || {
                kernel::matmul_packed(&a, &b, 1)
            })
            .stats
            .mean
            .as_nanos();
        let packed_mt_ns = runner
            .bench_value(&format!("native/packed_mt{mt}_n{n}"), || {
                kernel::matmul_packed(&a, &b, mt)
            })
            .stats
            .mean
            .as_nanos();
        rows.push(schema::KernelSizeRow { n, naive_ns, packed_ns, packed_mt_ns });
    }
    for r in &rows {
        println!(
            "  n={:4}: naive/packed = {:.2}x serial, {:.2}x with {mt} threads",
            r.n,
            r.naive_ns as f64 / r.packed_ns.max(1) as f64,
            r.naive_ns as f64 / r.packed_mt_ns.max(1) as f64,
        );
    }

    // --- alloc-count comparison: encode scratch reuse ---------------------
    // The worker encode used to allocate two fresh matrices per task;
    // the scratch path reuses one buffer per operand. Clone counts stay
    // zero on both (encode never clones), so the comparison is timing +
    // the clone counter pinning the decode-path invariant.
    let x = Matrix::random(256, 256, &mut rng);
    let blocks = split_blocks(&x);
    let coeffs = [1i32, -1, 0, 1];
    runner.bench_value("encode/alloc_per_task", || encode_operand(&coeffs, &blocks));
    let mut scratch = Matrix::zeros(0, 0);
    runner.bench("encode/scratch_reuse", || {
        encode_operand_into(&mut scratch, &coeffs, &blocks);
    });
    let clones_before = Matrix::clone_count();
    encode_operand_into(&mut scratch, &coeffs, &blocks);
    let _p = blocks[0].matmul(&blocks[1]);
    let encode_clones = Matrix::clone_count() - clones_before;
    println!("encode+matmul hot path matrix clones: {encode_clones} (expect 0)");

    // --- recursive + blocked reference points -----------------------------
    let a = Matrix::random(256, 256, &mut rng);
    let b = Matrix::random(256, 256, &mut rng);
    runner.bench_value("native/strassen_rec_n256_cut64", || {
        let cfg = RecursiveConfig { crossover: 64, max_depth: 8, ..Default::default() };
        strassen_mm(&a, &b, &cfg)
    });
    let a4 = split_blocks(&a);
    let b4 = split_blocks(&b);
    runner.bench_value("native/worker_product_bs128", || {
        let left = &a4[0] + &a4[3];
        let right = &b4[0] + &b4[3];
        left.matmul(&right)
    });

    // --- recursive-vs-flat crossover sweep --------------------------------
    // Leaves route through the SIMD microkernel when the CPU reports
    // the features, scalar packed otherwise; the recursion result is
    // cross-checked against the flat kernel at every point.
    let leaf_kind = if kernel::simd_available() {
        KernelKind::Simd
    } else {
        KernelKind::Packed
    };
    let sweep_sizes: &[usize] = if quick {
        &[256, 512]
    } else {
        &[256, 512, 1024, 2048, 4096]
    };
    let crossovers = [64usize, 128, 256, 512];
    let strassen_scheme = ft_strassen::algorithms::strassen();
    println!(
        "\nrecursive-vs-flat sweep (leaf kernel: {}):",
        leaf_kind.display_name()
    );
    let mut sweep_rows: Vec<schema::RecursiveSweepRow> = Vec::new();
    for &n in sweep_sizes {
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        let mut flat = Matrix::zeros(0, 0);
        let flat_ns = runner
            .bench(&format!("sweep/flat_{}_n{n}", leaf_kind.display_name()), || {
                kernel::matmul_into(leaf_kind, &a, &b, &mut flat, 1);
            })
            .stats
            .mean
            .as_nanos();
        let mut rec = Matrix::zeros(0, 0);
        let mut best_crossover = 0usize;
        let mut best_ns = u128::MAX;
        let mut points: Vec<schema::CrossoverPoint> = Vec::new();
        for &crossover in crossovers.iter().filter(|&&c| c < n) {
            let cfg = RecursiveConfig { crossover, max_depth: usize::MAX, leaf: leaf_kind };
            let rec_ns = runner
                .bench(&format!("sweep/rec_n{n}_c{crossover}"), || {
                    scheme_mm_into(&strassen_scheme, &a, &b, &mut rec, &cfg);
                })
                .stats
                .mean
                .as_nanos();
            assert!(
                rec.approx_eq(&flat, 2e-3),
                "recursive diverged from flat at n={n} crossover={crossover}: rel_err={}",
                rec.rel_error(&flat)
            );
            let speedup = flat_ns as f64 / rec_ns.max(1) as f64;
            println!("  n={n:4} crossover={crossover:3}: rec/flat speedup {speedup:.2}x");
            if rec_ns < best_ns {
                best_ns = rec_ns;
                best_crossover = crossover;
            }
            points.push(schema::CrossoverPoint { crossover, rec_ns, speedup });
        }
        sweep_rows.push(schema::RecursiveSweepRow { n, flat_ns, best_crossover, points });
    }

    // complexity model table
    println!("\nmultiplication counts (cutoff 32):");
    for n in [64u32, 128, 256, 512, 1024] {
        let s = multiplication_count(7, n as usize, 32);
        let d = multiplication_count(8, n as usize, 32);
        println!(
            "  n={n:5}: strassen {s:>14}  naive {d:>14}  ratio {:.3}",
            s as f64 / d as f64
        );
    }

    // --- PJRT path ----------------------------------------------------------
    let dir = Path::new("artifacts");
    match Runtime::new(dir) {
        Err(e) => println!("\npjrt benches skipped: {e}"),
        Ok(mut rt) => {
            println!("\npjrt: {}", rt.platform());
            for bs in rt.manifest().worker_block_sizes() {
                let blk: [Matrix; 4] =
                    std::array::from_fn(|_| Matrix::random(bs, bs, &mut rng));
                let blk2: [Matrix; 4] =
                    std::array::from_fn(|_| Matrix::random(bs, bs, &mut rng));
                rt.warmup(bs).unwrap();
                runner.bench_value(&format!("pjrt/worker_task_bs{bs}"), || {
                    rt.worker_task(&[1.0, 0.0, 0.0, 1.0], &blk, &[1.0, 0.0, 0.0, 1.0], &blk2)
                        .unwrap()
                });
                let products: Vec<Matrix> =
                    (0..16).map(|_| Matrix::random(bs, bs, &mut rng)).collect();
                let weights: Vec<f32> =
                    (0..16).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
                runner.bench_value(&format!("pjrt/decode_combine_bs{bs}"), || {
                    let refs: Vec<Option<&Matrix>> = products.iter().map(Some).collect();
                    rt.decode_combine(&weights, &refs, bs).unwrap()
                });
                runner.bench_value(&format!("pjrt/strassen_once_bs{bs}"), || {
                    rt.strassen_once(&blk, &blk2).unwrap()
                });
                let n = 2 * bs;
                let a = Matrix::random(n, n, &mut rng);
                let b = Matrix::random(n, n, &mut rng);
                runner.bench_value(&format!("pjrt/matmul_n{n}"), || {
                    rt.matmul(&a, &b).unwrap()
                });
            }
        }
    }

    let out = Path::new("target/bench_results");
    std::fs::create_dir_all(out).unwrap();
    runner.write_csv(&out.join("kernel_timings.csv")).unwrap();
    runner.write_json(&out.join("kernel_timings.json")).unwrap();

    // --- BENCH_kernel.json trajectory entry (repo root) -------------------
    // Schema (documented in README "Benchmark trajectories"): one object
    // per run with unix_time, quick, threads_mt, encode_clones and a
    // `sizes` array of {n, naive_ns, packed_ns, packed_mt_ns,
    // speedup_packed, speedup_packed_mt}.
    let unix_time = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let entry = schema::KernelEntry {
        unix_time,
        quick,
        threads_mt: mt,
        encode_clones,
        sizes: rows,
    }
    .render();
    let path = trajectory::append_to_repo_root("BENCH_kernel.json", &entry)
        .expect("write BENCH_kernel.json");
    println!("appended kernel trajectory to {}", path.display());

    // --- BENCH_recursive.json trajectory entry (repo root) ----------------
    // Schema (documented in README "Benchmark trajectories"): one object
    // per run with unix_time, quick, kernel (the leaf microkernel that
    // ran) and a `sweep` array of {n, flat_ns, best_crossover,
    // points: [{crossover, rec_ns, speedup}]}.
    let entry = schema::RecursiveEntry {
        unix_time,
        quick,
        kernel: leaf_kind.display_name().into(),
        sweep: sweep_rows,
    }
    .render();
    let path = trajectory::append_to_repo_root("BENCH_recursive.json", &entry)
        .expect("write BENCH_recursive.json");
    println!("appended recursive trajectory to {}", path.display());
}
