//! Bench: fleet-scale discrete-event campaigns — sweep p_e over a
//! 10k-node fleet (1k under `FT_BENCH_QUICK=1`) for each scheduling
//! policy on the nested sw+2psmm² plan (256 leaves/job), compare the
//! measured failure rate against the paper's nested eq. (9) curve, and
//! append one `BENCH_sim.json` entry per policy.
//!
//! The fleet is deliberately non-uniform (bimodal speeds, metered
//! links, stragglers) so the policies actually differ: fastest-first
//! should beat random on mean completion, locality-aware should move
//! fewer bytes, speculative should trim the straggler tail.

use std::time::{Duration, SystemTime, UNIX_EPOCH};

use ft_strassen::bench::schema::{SimCell, SimEntry};
use ft_strassen::bench::trajectory::append_to_repo_root;
use ft_strassen::coding::fc::fc_table;
use ft_strassen::coding::nested::NestedTaskSet;
use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::coding::theory::{log_pe_grid, nested_failure_probability};
use ft_strassen::coordinator::worker::FaultPlan;
use ft_strassen::sim::des::{policy_by_name, ArrivalProcess, Campaign, FleetSpec, LinkModel, SimPlan};
use ft_strassen::sim::latency::LatencyModel;

fn main() {
    let quick = std::env::var("FT_BENCH_QUICK").as_deref() == Ok("1");
    let (workers, jobs, points) = if quick { (1_000, 60, 3) } else { (10_000, 300, 5) };
    let seed = 42u64;

    let plan = SimPlan::Nested(NestedTaskSet::compose(
        TaskSet::strassen_winograd(2),
        TaskSet::strassen_winograd(2),
    ));
    let leaves = plan.num_leaves();
    let outer_fc = fc_table(&TaskSet::strassen_winograd(2));
    let inner_fc = fc_table(&TaskSet::strassen_winograd(2));

    let fleet = FleetSpec {
        workers,
        rack_size: 32,
        p_rack: 0.0,
        speed: LatencyModel::Bimodal { base: 1.0, p_slow: 0.15, factor: 4.0 },
        leaf_latency: LatencyModel::ShiftedExp { shift: 0.005, rate: 200.0 },
        link: LinkModel { latency_s: 0.0002, bytes_per_s: 1.25e9 },
    };
    let arrivals = ArrivalProcess::Poisson { count: jobs, rate: 200.0 };
    let grid = log_pe_grid(points);

    let unix_time =
        SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs()).unwrap_or(0);

    println!(
        "=== fleet sim: {} | {workers} workers, {jobs} jobs, {leaves} leaves/job{} ===",
        plan.name(),
        if quick { " (quick)" } else { "" },
    );
    println!(
        "{:<12} {:>8} {:>12} {:>12} {:>10} {:>10} {:>8} {:>12}",
        "policy", "p_e", "theory_pf", "measured_pf", "mean_s", "p95_s", "backups", "net_bytes"
    );

    for name in ["random", "fastest", "locality", "speculative"] {
        let mut cells = Vec::new();
        for &p_e in &grid {
            let mut policy = policy_by_name(name).unwrap();
            let campaign = Campaign {
                fleet,
                arrivals: arrivals.clone(),
                fault: FaultPlan {
                    p_fail: p_e,
                    p_straggle: (0.2f64).min(1.0 - p_e),
                    delay: Duration::from_millis(40),
                },
                block_bytes: 16 * 16 * 8,
                seed,
                max_attempts: 4,
                heap_capacity: jobs * leaves / 4,
                record_trace: false,
            };
            let summary = campaign.run(&plan, policy.as_mut()).summary;
            let theory = nested_failure_probability(&outer_fc, &inner_fc, p_e);
            println!(
                "{:<12} {:>8.4} {:>12.3e} {:>12.4} {:>10.4} {:>10.4} {:>8} {:>12}",
                name,
                p_e,
                theory,
                summary.measured_pf.mean,
                summary.mean_completion_s,
                summary.p95_completion_s,
                summary.backups,
                summary.network_bytes
            );
            cells.push(SimCell {
                p_e,
                theory_pf: theory,
                measured_pf: summary.measured_pf.mean,
                std_err: summary.measured_pf.std_err,
                mean_completion_s: summary.mean_completion_s,
                p95_completion_s: summary.p95_completion_s,
                backups: summary.backups,
                network_bytes: summary.network_bytes,
            });
        }
        let entry = SimEntry {
            unix_time,
            plan: plan.name().to_string(),
            policy: name.to_string(),
            workers,
            jobs,
            seed,
            quick,
            trace_digest: None,
            cells,
        };
        match append_to_repo_root("BENCH_sim.json", &entry.render()) {
            Ok(path) => println!("appended {name} entry to {}", path.display()),
            Err(e) => eprintln!("warning: could not append BENCH_sim.json: {e}"),
        }
    }
}
