//! Bench: regenerate the paper's Tables I & II and the §IV search
//! outputs (relation counts, PSMM selection), timing Algorithm 1 at
//! increasing K and the two decoders across all failure patterns —
//! the span-vs-peeling ablation called out in DESIGN.md.

use std::path::Path;

use ft_strassen::algebra::form::{BilinearForm, Target};
use ft_strassen::bench::harness::BenchRunner;
use ft_strassen::coding::decoder::PeelingDecoder;
use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::search::psmm::select_psmms;
use ft_strassen::search::relations::relations_for_target;
use ft_strassen::search::searchlp::{search_lp, SearchOptions};

fn main() {
    let mut runner = BenchRunner::from_env();
    let ts = TaskSet::strassen_winograd(0);
    let names = ts.names();
    let forms = ts.forms();

    // --- Table I: the elementary-product table --------------------------
    println!("=== Table I: elementary products M_p · B_q ===");
    for q in 0..4 {
        let row: Vec<String> = (0..4)
            .map(|p| format!("{}", BilinearForm::elementary(p, q)))
            .collect();
        println!("  {}", row.join("  "));
    }

    // --- Table II: local relations for C11 -------------------------------
    let res = search_lp(&forms, &SearchOptions::default());
    println!("\n=== Table II: local relations involving C11 (K <= 8) ===");
    for r in relations_for_target(&res, Target::C11) {
        println!("  {}", r.render(&names));
    }
    println!(
        "\ntotal local relations (all targets): {}  parity candidates: {}",
        res.num_relations(),
        res.parities.len()
    );

    // --- search timings at increasing K ----------------------------------
    for k in [4usize, 6, 8] {
        runner.bench_value(&format!("search_lp/K={k}"), || {
            search_lp(&forms, &SearchOptions { max_k: k, ..Default::default() }).num_relations()
        });
    }
    runner.bench_value("select_psmms/2", || {
        select_psmms(&forms, 2, &SearchOptions::default()).len()
    });

    // --- decoder ablation: peeling vs span over all 2^14 patterns --------
    // Three peeling relation sets of increasing size vs the exact span
    // decoder: minimal K<=8, unfiltered K<=8, unfiltered K<=10.
    let m = ts.num_tasks();
    let oracle = ft_strassen::coding::fc::DecodeOracle::build(&ts);
    let span_ok: u64 = (0u64..(1 << m))
        .filter(|&f| oracle.is_decodable(f))
        .count() as u64;
    println!();
    let mut last_peeler = None;
    for (tag, opts) in [
        ("minimal K<=8", SearchOptions { max_k: 8, minimal_only: true, collect_parities: false }),
        ("unfiltered K<=8", SearchOptions { max_k: 8, minimal_only: false, collect_parities: false }),
        ("unfiltered K<=10", SearchOptions { max_k: 10, minimal_only: false, collect_parities: false }),
    ] {
        let peeler = PeelingDecoder::new(&ts, &opts);
        let mut peel_ok = 0u64;
        let mut gap = 0u64;
        for failed in 0u64..(1 << m) {
            let finished = !failed & ((1 << m) - 1);
            let p = peeler.run(finished).decoded;
            peel_ok += p as u64;
            gap += (oracle.is_decodable(failed) && !p) as u64;
        }
        println!(
            "decoder ablation [{tag}, {} relations] over {} patterns: \
             span={span_ok} peel={peel_ok} gap={gap}",
            peeler.num_relations(),
            1u64 << m
        );
        last_peeler = Some(peeler);
    }
    let peeler = last_peeler.unwrap();
    runner.bench_value("peeling_decode/full_pattern", || {
        peeler.run((1 << m) - 1).steps
    });
    runner.bench_value("span_decode/full_pattern", || {
        ts.decodable_with_failures(0)
    });

    let out = Path::new("target/bench_results");
    std::fs::create_dir_all(out).unwrap();
    runner.write_csv(&out.join("table2_timings.csv")).unwrap();
}
