//! Bench: end-to-end serving throughput/latency under stragglers for the
//! schemes the paper compares — the systems-level counterpart of Fig. 2 —
//! plus the **in-flight depth sweep** of the multiplexed coordinator
//! (depth 1 = the paper's sequential master) and a **decode alloc
//! count**, appended as a trajectory entry to `BENCH_e2e.json` at the
//! repo root (via `bench::trajectory`, cwd-independent) so throughput
//! is trackable across PRs.
//!
//! Uses the native backend by default (hermetic); set FT_BENCH_PJRT=1
//! to route worker products through the AOT Pallas artifacts.

use std::path::Path;
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use ft_strassen::bench::{schema, trajectory};
use ft_strassen::coding::nested::NestedTaskSet;
use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::coordinator::master::MasterConfig;
use ft_strassen::coordinator::server::{MmServer, ServerConfig};
use ft_strassen::coordinator::task::DispatchPlan;
use ft_strassen::coordinator::worker::{Backend, FaultPlan};
use ft_strassen::runtime::service::ComputeService;

fn server_cfg(fault: FaultPlan, depth: usize) -> ServerConfig {
    ServerConfig {
        master: MasterConfig {
            deadline: Duration::from_secs(10),
            fault,
            seed: 1,
            fallback_local: true,
            collect_all: false,
        },
        queue_cap: 4096,
        inflight_depth: depth,
    }
}

fn main() {
    let quick = std::env::var("FT_BENCH_QUICK").as_deref() == Ok("1");
    let jobs = if quick { 8 } else { 48 };
    let n = 256usize;

    let (backend, _svc);
    if std::env::var("FT_BENCH_PJRT").as_deref() == Ok("1") {
        let svc = ComputeService::spawn(Path::new("artifacts"), &[n / 2])
            .expect("artifacts required for FT_BENCH_PJRT=1");
        println!("backend: pjrt ({})", svc.handle().platform().unwrap());
        backend = Backend::Pjrt(svc.handle());
        _svc = Some(svc);
    } else {
        println!("backend: native (FT_BENCH_PJRT=1 for the artifact path)");
        backend = Backend::Native;
        _svc = None;
    }

    let fault = FaultPlan {
        p_fail: 0.03,
        p_straggle: 0.15,
        delay: Duration::from_millis(25),
    };
    println!(
        "workload: {jobs} jobs of {n}x{n}, p_fail={}, p_straggle={} ({:?})\n",
        fault.p_fail, fault.p_straggle, fault.delay
    );
    println!(
        "{:<20} {:>9} {:>12} {:>12} {:>9} {:>9} {:>8}",
        "scheme", "jobs/s", "mean", "p95", "decoded", "fallback", "workers"
    );

    let mut rows = String::from("scheme,jobs_per_s,mean_ns,p95_ns,decoded,fell_back\n");
    let schemes: Vec<(&str, TaskSet)> = vec![
        ("strassen-x1 (7)", TaskSet::replication(&ft_strassen::algorithms::strassen(), 1)),
        ("strassen-x2 (14)", TaskSet::replication(&ft_strassen::algorithms::strassen(), 2)),
        ("sw+0psmm (14)", TaskSet::strassen_winograd(0)),
        ("sw+1psmm (15)", TaskSet::strassen_winograd(1)),
        ("sw+2psmm (16)", TaskSet::strassen_winograd(2)),
        ("strassen-x3 (21)", TaskSet::replication(&ft_strassen::algorithms::strassen(), 3)),
    ];
    for (name, set) in schemes {
        // Depth 1 keeps the scheme table comparable with the paper's
        // sequential master; the sweep below measures multiplexing.
        let mut server = MmServer::new(set, backend.clone(), server_cfg(fault, 1));
        let r = server.run_workload(jobs, n, 1).expect("workload");
        println!(
            "{:<20} {:>9.2} {:>12.3?} {:>12.3?} {:>9} {:>9} {:>8.1}",
            name,
            r.throughput_jobs_per_s,
            r.mean_latency,
            r.p95_latency,
            r.decoded,
            r.fell_back,
            r.mean_finished_workers
        );
        rows.push_str(&format!(
            "{},{},{},{},{},{}\n",
            name,
            r.throughput_jobs_per_s,
            r.mean_latency.as_nanos(),
            r.p95_latency.as_nanos(),
            r.decoded,
            r.fell_back
        ));
        server.shutdown();
    }

    let out = Path::new("target/bench_results");
    std::fs::create_dir_all(out).unwrap();
    std::fs::write(out.join("e2e_throughput.csv"), rows).unwrap();
    println!("\nwrote target/bench_results/e2e_throughput.csv");

    // --- in-flight depth sweep (the multiplexed-coordinator headline) ----
    // Small n makes worker compute cheap, so job latency is dominated by
    // straggler waits — exactly the regime where multiplexing pays: a
    // waiting job's slots are free for the next jobs' items.
    let sweep_jobs = if quick { 24 } else { 120 };
    let sweep_n = 64usize;
    let sweep_fault = FaultPlan {
        p_fail: 0.02,
        p_straggle: 0.30,
        delay: Duration::from_millis(25),
    };
    println!(
        "\ndepth sweep: sw+2psmm, {sweep_jobs} jobs of {sweep_n}x{sweep_n}, \
         p_fail={}, p_straggle={} ({:?})",
        sweep_fault.p_fail, sweep_fault.p_straggle, sweep_fault.delay
    );
    println!(
        "{:<8} {:>9} {:>12} {:>12} {:>9} {:>9}",
        "depth", "jobs/s", "mean", "p95", "decoded", "fallback"
    );
    let mut sweep: Vec<(usize, f64, u128, u128)> = Vec::new();
    for depth in [1usize, 2, 4, 8] {
        let mut server = MmServer::new(
            TaskSet::strassen_winograd(2),
            backend.clone(),
            server_cfg(sweep_fault, depth),
        );
        let r = server.run_workload(sweep_jobs, sweep_n, 1).expect("sweep workload");
        println!(
            "{:<8} {:>9.2} {:>12.3?} {:>12.3?} {:>9} {:>9}",
            depth,
            r.throughput_jobs_per_s,
            r.mean_latency,
            r.p95_latency,
            r.decoded,
            r.fell_back
        );
        sweep.push((
            depth,
            r.throughput_jobs_per_s,
            r.mean_latency.as_nanos(),
            r.p95_latency.as_nanos(),
        ));
        server.shutdown();
    }
    let base = sweep[0].1.max(1e-9);
    let speedup4 = sweep.iter().find(|s| s.0 == 4).map(|s| s.1 / base).unwrap_or(0.0);
    println!("depth-4 speedup over sequential: {speedup4:.2}x");

    // --- decode alloc count: zero matrix clones per solve -----------------
    // Drive one flat job's decode state machine by hand and count deep
    // Matrix copies across the solve+assemble; the borrowed-slice
    // combine path must clone nothing (tests/decode_alloc.rs pins this,
    // the bench records it in the trajectory).
    let decode_clones = {
        use ft_strassen::coordinator::job::JobState;
        use ft_strassen::coordinator::task::TaskGraph;
        use ft_strassen::coordinator::worker::WorkerReply;
        use ft_strassen::linalg::blocked::{encode_operand, split_blocks};
        use ft_strassen::linalg::matrix::Matrix;
        use ft_strassen::sim::rng::Rng;
        use std::sync::Arc;
        use std::time::Instant;
        let graph = TaskGraph::new(TaskSet::strassen_winograd(2));
        let mut rng = Rng::seeded(7);
        let a = Matrix::random(64, 64, &mut rng);
        let b = Matrix::random(64, 64, &mut rng);
        let a4 = split_blocks(&a);
        let b4 = split_blocks(&b);
        let now = Instant::now();
        let mut job = JobState::new(
            &DispatchPlan::Flat(graph.clone()),
            1,
            Arc::new(a4.clone()),
            Arc::new(b4.clone()),
            now,
            now,
            now + Duration::from_secs(5),
            0,
            0,
            true,
        );
        for spec in &graph.specs {
            let p = encode_operand(&spec.int_ca(), &a4)
                .matmul(&encode_operand(&spec.int_cb(), &b4));
            job.on_reply(WorkerReply {
                job_id: 1,
                task_id: spec.id,
                product: Ok(p),
                compute_time: Duration::ZERO,
            });
        }
        let before = Matrix::clone_count();
        let c = job.assemble(&Backend::Native).expect("decodable");
        assert_eq!(c.shape(), (64, 64));
        Matrix::clone_count() - before
    };
    println!("decode solve matrix clones: {decode_clones} (expect 0)");

    // Append one trajectory entry to BENCH_e2e.json at the repo root.
    // Schema (documented in README "Benchmark trajectories"): unix_time,
    // scheme, n, jobs, fault params, quick, speedup_depth4_vs_1,
    // decode_clones_per_solve, depths[{depth, jobs_per_s, mean_ns,
    // p95_ns}].
    let unix_time = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let entry = schema::E2eEntry {
        unix_time,
        scheme: "sw+2psmm".into(),
        n: sweep_n,
        jobs: sweep_jobs,
        p_fail: sweep_fault.p_fail,
        p_straggle: sweep_fault.p_straggle,
        delay_ms: sweep_fault.delay.as_millis(),
        quick,
        speedup_depth4_vs_1: speedup4,
        decode_clones_per_solve: decode_clones,
        depths: sweep
            .iter()
            .map(|&(depth, jobs_per_s, mean_ns, p95_ns)| schema::DepthPoint {
                depth,
                jobs_per_s,
                mean_ns,
                p95_ns,
            })
            .collect(),
    }
    .render();
    let traj = trajectory::append_to_repo_root("BENCH_e2e.json", &entry)
        .expect("write BENCH_e2e.json");
    println!("appended depth-sweep trajectory to {}", traj.display());

    // --- nested vs flat at equal node count ------------------------------
    // Both configurations get a 16-thread fleet. Flat sw+2psmm sends 16
    // items per job; nested sw+2psmm:sw+2psmm fans out 256 leaves that
    // multiplex onto the same 16 slots (with eager group cancellation
    // pruning most of them). The nested scheme pays compute overhead for
    // a first_loss of 9 leaf failures vs the flat scheme's 3.
    let nested_jobs = if quick { 6 } else { 24 };
    let nested_n = 64usize;
    let nested_fault = FaultPlan {
        p_fail: 0.02,
        p_straggle: 0.15,
        delay: Duration::from_millis(10),
    };
    println!(
        "\nnested vs flat (16 workers each): {nested_jobs} jobs of {nested_n}x{nested_n}, \
         p_fail={}, p_straggle={} ({:?})",
        nested_fault.p_fail, nested_fault.p_straggle, nested_fault.delay
    );
    println!(
        "{:<26} {:>6} {:>9} {:>12} {:>12} {:>9} {:>9}",
        "scheme", "items", "jobs/s", "mean", "p95", "decoded", "fallback"
    );
    let mut nested_rows =
        String::from("scheme,items_per_job,jobs_per_s,mean_ns,p95_ns,decoded,fell_back\n");
    let variants: Vec<(&str, DispatchPlan)> = vec![
        ("sw+2psmm (flat)", DispatchPlan::flat(TaskSet::strassen_winograd(2))),
        (
            "sw+2psmm:sw+2psmm",
            DispatchPlan::nested(NestedTaskSet::compose(
                TaskSet::strassen_winograd(2),
                TaskSet::strassen_winograd(2),
            )),
        ),
    ];
    for (name, plan) in variants {
        let items = plan.num_work_items();
        let mut server = MmServer::with_plan(
            plan,
            backend.clone(),
            server_cfg(nested_fault, 4),
            Some(16),
        );
        let r = server.run_workload(nested_jobs, nested_n, 1).expect("nested workload");
        println!(
            "{:<26} {:>6} {:>9.2} {:>12.3?} {:>12.3?} {:>9} {:>9}",
            name,
            items,
            r.throughput_jobs_per_s,
            r.mean_latency,
            r.p95_latency,
            r.decoded,
            r.fell_back
        );
        nested_rows.push_str(&format!(
            "{},{},{},{},{},{},{}\n",
            name,
            items,
            r.throughput_jobs_per_s,
            r.mean_latency.as_nanos(),
            r.p95_latency.as_nanos(),
            r.decoded,
            r.fell_back
        ));
        server.shutdown();
    }
    std::fs::write(out.join("nested_vs_flat.csv"), nested_rows).unwrap();
    println!("wrote target/bench_results/nested_vs_flat.csv");

    // --- multi-tenant serving sweep → BENCH_serve.json -------------------
    // tenants × batch window × cache on/off over a straggler-heavy
    // stream whose left operands repeat (4 distinct A matrices), so the
    // encoded-operand cache has something to hit. Closed loop at the
    // in-flight depth, like run_workload, so latencies measure service
    // time rather than synthetic backlog wait.
    {
        use ft_strassen::coordinator::tier::{TenantSpec, TierConfig};
        use ft_strassen::linalg::matrix::Matrix;
        use ft_strassen::sim::rng::Rng;
        use std::time::Instant;
        let serve_jobs = if quick { 16 } else { 64 };
        let serve_n = 64usize;
        let serve_fault = FaultPlan {
            p_fail: 0.0,
            p_straggle: 0.3,
            delay: Duration::from_millis(25),
        };
        println!(
            "\nserving sweep: sw+2psmm, {serve_jobs} jobs of {serve_n}x{serve_n}, \
             repeated left operands, p_straggle={} ({:?})",
            serve_fault.p_straggle, serve_fault.delay
        );
        println!(
            "{:<8} {:>7} {:>6} {:>9} {:>12} {:>12} {:>9} {:>9}",
            "tenants", "window", "cache", "jobs/s", "mean", "p95", "hit-rate", "fallback"
        );
        let mut cells: Vec<schema::ServeCell> = Vec::new();
        for tenants in [1usize, 2] {
            for window in [1usize, 4] {
                for cache_cap in [0usize, 16] {
                    let roster = if tenants == 1 {
                        vec![TenantSpec::unbounded("solo")]
                    } else {
                        vec![
                            TenantSpec::new("heavy", 3, 8),
                            TenantSpec::new("light", 1, 8),
                        ]
                    };
                    let mut server = MmServer::with_tier_config(
                        DispatchPlan::flat(TaskSet::strassen_winograd(2)),
                        backend.clone(),
                        TierConfig {
                            master: MasterConfig {
                                deadline: Duration::from_secs(10),
                                fault: serve_fault,
                                seed: 1,
                                fallback_local: true,
                                collect_all: false,
                            },
                            depth: 4,
                            queue_cap: 4096,
                            tenants: roster,
                            batch_window: window,
                            cache_cap,
                        },
                        None,
                    );
                    let names = server.tenant_names();
                    let mut rng = Rng::seeded(9);
                    let lefts: Vec<Matrix> = (0..4)
                        .map(|_| Matrix::random(serve_n, serve_n, &mut rng))
                        .collect();
                    let t0 = Instant::now();
                    for i in 0..serve_jobs {
                        while server.queue_depth() >= 8 {
                            server.drain(1).expect("serve sweep drain");
                        }
                        let b = Matrix::random(serve_n, serve_n, &mut rng);
                        let tenant = names[i % names.len()].clone();
                        server
                            .submit_as(&tenant, lefts[i % lefts.len()].clone(), b)
                            .expect("serve sweep submit");
                    }
                    while server.queue_depth() > 0 {
                        server.drain(usize::MAX).expect("serve sweep drain");
                    }
                    let r = server.report(t0.elapsed());
                    let reg = server.registry();
                    let hits = reg.counter("cache_hits").get();
                    let misses = reg.counter("cache_misses").get();
                    let hit_rate = if hits + misses > 0 {
                        hits as f64 / (hits + misses) as f64
                    } else {
                        0.0
                    };
                    println!(
                        "{:<8} {:>7} {:>6} {:>9.2} {:>12.3?} {:>12.3?} {:>9.3} {:>9}",
                        tenants,
                        window,
                        cache_cap,
                        r.throughput_jobs_per_s,
                        r.mean_latency,
                        r.p95_latency,
                        hit_rate,
                        r.fell_back
                    );
                    cells.push(schema::ServeCell {
                        tenants,
                        batch_window: window,
                        cache_cap,
                        jobs_per_s: r.throughput_jobs_per_s,
                        mean_ns: r.mean_latency.as_nanos(),
                        p95_ns: r.p95_latency.as_nanos(),
                        cache_hit_rate: hit_rate,
                        fell_back: r.fell_back,
                    });
                    server.shutdown();
                }
            }
        }
        let entry = schema::ServeEntry {
            unix_time,
            scheme: "sw+2psmm".into(),
            n: serve_n,
            jobs: serve_jobs,
            p_straggle: serve_fault.p_straggle,
            delay_ms: serve_fault.delay.as_millis(),
            quick,
            trace_digest: None,
            cells,
        }
        .render();
        let traj = trajectory::append_to_repo_root("BENCH_serve.json", &entry)
            .expect("write BENCH_serve.json");
        println!("appended serving-sweep trajectory to {}", traj.display());
    }

    // --- coordinator overhead microbench (native, no faults) -------------
    // n=16 makes worker compute negligible -> isolates dispatch + online
    // decode + assembly; n=256 shows the realistic mix.
    use ft_strassen::bench::harness::BenchRunner;
    use ft_strassen::coordinator::master::Master;
    use ft_strassen::linalg::blocked::{join_blocks, split_blocks};
    use ft_strassen::linalg::matrix::Matrix;
    use ft_strassen::sim::rng::Rng;
    let mut runner = BenchRunner::from_env();
    let mut rng = Rng::seeded(5);
    for n in [16usize, 64, 256] {
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        let mut master = Master::new(
            TaskSet::strassen_winograd(2),
            Backend::Native,
            MasterConfig {
                deadline: Duration::from_secs(10),
                fault: FaultPlan::NONE,
                seed: 1,
                fallback_local: false,
                collect_all: false,
            },
        );
        runner.bench_value(&format!("master/multiply_n{n}"), || {
            master.multiply(&a, &b).unwrap()
        });
        master.shutdown();
    }
    let x = Matrix::random(256, 256, &mut rng);
    runner.bench_value("master/split_blocks_n256", || split_blocks(&x));
    let blocks = split_blocks(&x);
    runner.bench_value("master/join_blocks_n256", || join_blocks(&blocks));
    runner.write_csv(&out.join("coordinator_timings.csv")).unwrap();
    runner.write_json(&out.join("coordinator_timings.json")).unwrap();
}
