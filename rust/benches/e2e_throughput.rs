//! Bench: end-to-end serving throughput/latency under stragglers for the
//! schemes the paper compares — the systems-level counterpart of Fig. 2.
//! Reported per scheme: jobs/s, mean and p95 latency, decode success.
//!
//! Uses the native backend by default (hermetic); set FT_BENCH_PJRT=1
//! to route worker products through the AOT Pallas artifacts.

use std::path::Path;
use std::time::Duration;

use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::coordinator::master::MasterConfig;
use ft_strassen::coordinator::server::{MmServer, ServerConfig};
use ft_strassen::coordinator::worker::{Backend, FaultPlan};
use ft_strassen::runtime::service::ComputeService;

fn main() {
    let quick = std::env::var("FT_BENCH_QUICK").as_deref() == Ok("1");
    let jobs = if quick { 8 } else { 48 };
    let n = 256usize;

    let (backend, _svc);
    if std::env::var("FT_BENCH_PJRT").as_deref() == Ok("1") {
        let svc = ComputeService::spawn(Path::new("artifacts"), &[n / 2])
            .expect("artifacts required for FT_BENCH_PJRT=1");
        println!("backend: pjrt ({})", svc.handle().platform().unwrap());
        backend = Backend::Pjrt(svc.handle());
        _svc = Some(svc);
    } else {
        println!("backend: native (FT_BENCH_PJRT=1 for the artifact path)");
        backend = Backend::Native;
        _svc = None;
    }

    let fault = FaultPlan {
        p_fail: 0.03,
        p_straggle: 0.15,
        delay: Duration::from_millis(25),
    };
    println!(
        "workload: {jobs} jobs of {n}x{n}, p_fail={}, p_straggle={} ({:?})\n",
        fault.p_fail, fault.p_straggle, fault.delay
    );
    println!(
        "{:<20} {:>9} {:>12} {:>12} {:>9} {:>9} {:>8}",
        "scheme", "jobs/s", "mean", "p95", "decoded", "fallback", "workers"
    );

    let mut rows = String::from("scheme,jobs_per_s,mean_ns,p95_ns,decoded,fell_back\n");
    let schemes: Vec<(&str, TaskSet)> = vec![
        ("strassen-x1 (7)", TaskSet::replication(&ft_strassen::algorithms::strassen(), 1)),
        ("strassen-x2 (14)", TaskSet::replication(&ft_strassen::algorithms::strassen(), 2)),
        ("sw+0psmm (14)", TaskSet::strassen_winograd(0)),
        ("sw+1psmm (15)", TaskSet::strassen_winograd(1)),
        ("sw+2psmm (16)", TaskSet::strassen_winograd(2)),
        ("strassen-x3 (21)", TaskSet::replication(&ft_strassen::algorithms::strassen(), 3)),
    ];
    for (name, set) in schemes {
        let mut server = MmServer::new(
            set,
            backend.clone(),
            ServerConfig {
                master: MasterConfig {
                    deadline: Duration::from_secs(10),
                    fault,
                    seed: 1,
                    fallback_local: true,
                },
                queue_cap: 4096,
            },
        );
        let r = server.run_workload(jobs, n, 1).expect("workload");
        println!(
            "{:<20} {:>9.2} {:>12.3?} {:>12.3?} {:>9} {:>9} {:>8.1}",
            name,
            r.throughput_jobs_per_s,
            r.mean_latency,
            r.p95_latency,
            r.decoded,
            r.fell_back,
            r.mean_finished_workers
        );
        rows.push_str(&format!(
            "{},{},{},{},{},{}\n",
            name,
            r.throughput_jobs_per_s,
            r.mean_latency.as_nanos(),
            r.p95_latency.as_nanos(),
            r.decoded,
            r.fell_back
        ));
        server.shutdown();
    }

    let out = Path::new("target/bench_results");
    std::fs::create_dir_all(out).unwrap();
    std::fs::write(out.join("e2e_throughput.csv"), rows).unwrap();
    println!("\nwrote target/bench_results/e2e_throughput.csv");

    // --- coordinator overhead microbench (native, no faults) -------------
    // n=16 makes worker compute negligible -> isolates dispatch + online
    // decode + assembly; n=256 shows the realistic mix.
    use ft_strassen::bench::harness::BenchRunner;
    use ft_strassen::coordinator::master::Master;
    use ft_strassen::linalg::blocked::{join_blocks, split_blocks};
    use ft_strassen::linalg::matrix::Matrix;
    use ft_strassen::sim::rng::Rng;
    let mut runner = BenchRunner::from_env();
    let mut rng = Rng::seeded(5);
    for n in [16usize, 64, 256] {
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        let mut master = Master::new(
            TaskSet::strassen_winograd(2),
            Backend::Native,
            MasterConfig {
                deadline: Duration::from_secs(10),
                fault: FaultPlan::NONE,
                seed: 1,
                fallback_local: false,
            },
        );
        runner.bench_value(&format!("master/multiply_n{n}"), || {
            master.multiply(&a, &b).unwrap()
        });
        master.shutdown();
    }
    let x = Matrix::random(256, 256, &mut rng);
    runner.bench_value("master/split_blocks_n256", || split_blocks(&x));
    let blocks = split_blocks(&x);
    runner.bench_value("master/join_blocks_n256", || join_blocks(&blocks));
    runner.write_csv(&out.join("coordinator_timings.csv")).unwrap();
}
