//! Bench: regenerate the paper's Fig. 2 (its only figure) — P_f vs p_e
//! for all six schemes, theory + Monte Carlo — and time the analytical
//! pipeline (FC-table computation, eq. (9) evaluation, MC trial rate).
//!
//! Output: the Fig.-2 table + CSV at target/bench_results/fig2.csv.
//! `FT_BENCH_QUICK=1` shrinks budgets for smoke runs.

use std::path::Path;

use ft_strassen::bench::harness::BenchRunner;
use ft_strassen::bench::plot::{ascii_loglog, Series};
use ft_strassen::coding::fc::{fc_table, DecodeOracle};
use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::coding::theory::failure_probability;
use ft_strassen::sim::montecarlo::MonteCarlo;

fn pe_grid(points: usize) -> Vec<f64> {
    let (lo, hi) = (5e-3f64.ln(), 0.5f64.ln());
    (0..points)
        .map(|i| (lo + (hi - lo) * i as f64 / (points - 1) as f64).exp())
        .collect()
}

fn main() {
    let quick = std::env::var("FT_BENCH_QUICK").as_deref() == Ok("1");
    let trials: u64 = if quick { 20_000 } else { 200_000 };
    let mut runner = BenchRunner::from_env();

    // --- the figure itself -------------------------------------------------
    let schemes = TaskSet::fig2_schemes();
    let grid = pe_grid(9);
    let mut series = Vec::new();
    let mut csv = String::from("scheme,p_e,theory_pf,mc_pf,mc_stderr\n");
    println!("=== Fig. 2 data (theory | mc, {trials} trials) ===");
    for ts in &schemes {
        let fc = fc_table(ts);
        let oracle = DecodeOracle::build(ts);
        let mut pts = Vec::new();
        for &p in &grid {
            let theory = failure_probability(&fc, p);
            let mc = MonteCarlo::new(trials, 1)
                .failure_probability(p, ts.num_tasks(), |m| oracle.is_decodable(m));
            csv.push_str(&format!(
                "{},{p},{theory},{},{}\n",
                ts.name, mc.mean, mc.std_err
            ));
            pts.push((p, theory));
        }
        series.push(Series::new(ts.name.clone(), pts));
    }
    println!("{}", ascii_loglog(&series, 72, 22));

    // --- timings ------------------------------------------------------------
    runner.bench_value("fc_table/sw+2psmm (2^16 patterns)", || {
        fc_table(&TaskSet::strassen_winograd(2)).counts.len()
    });
    runner.bench_value("fc_table/strassen_x3 (structural)", || {
        fc_table(&TaskSet::replication(&ft_strassen::algorithms::strassen(), 3))
            .counts
            .len()
    });
    let fc = fc_table(&TaskSet::strassen_winograd(2));
    runner.bench_value("eq9_eval/sw+2psmm", || failure_probability(&fc, 0.1));
    let ts = TaskSet::strassen_winograd(2);
    runner.bench_value("mc_10k_trials/sw+2psmm (exact GE)", || {
        MonteCarlo::new(10_000, 1)
            .failure_probability(0.1, ts.num_tasks(), |m| ts.decodable_with_failures(m))
            .mean
    });
    let oracle = DecodeOracle::build(&ts);
    runner.bench_value("mc_10k_trials/sw+2psmm (oracle table)", || {
        MonteCarlo::new(10_000, 1)
            .failure_probability(0.1, ts.num_tasks(), |m| oracle.is_decodable(m))
            .mean
    });

    let out = Path::new("target/bench_results");
    std::fs::create_dir_all(out).unwrap();
    std::fs::write(out.join("fig2.csv"), csv).unwrap();
    runner.write_csv(&out.join("fig2_timings.csv")).unwrap();
    println!("wrote target/bench_results/fig2.csv");
}
